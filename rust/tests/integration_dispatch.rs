//! Dispatch-layer integration: the registry visitor is the crate's one
//! substrate dispatch point, so this suite drives every preset through
//! the same generic bodies the CLI commands use — path, fit, predict,
//! λ_max, mine — and then pins the `PathDriver` refactor with a
//! cross-engine-shape differential: every (forest × range-chunk ×
//! threads) shape must reproduce the baseline path bit for bit.

use spp::coordinator::{run_experiment, ExperimentSpec, Method};
use spp::data::registry::{self, RegistrySubstrate, SubstrateVisitor};
use spp::mining::{PatternNode, TreeVisitor, Walk};
use spp::model::SparsePatternModel;
use spp::path::{PathConfig, PathResult};
use spp::screening::lambda_max::{lambda_max, LambdaMax};
use spp::serve::compiled::CompiledModel;
use spp::solver::Task;
use spp::SppEstimator;

/// ~60 records whatever the preset's paper n (synth-xxl's is 25M).
fn tiny_scale(info: &registry::DatasetInfo) -> f64 {
    (60.0 / info.paper_n as f64).min(1.0)
}

fn tiny_cfg(maxpat: usize) -> PathConfig {
    PathConfig {
        n_lambdas: 6,
        lambda_min_ratio: 0.1,
        maxpat,
        ..PathConfig::default()
    }
}

/// The naive per-pattern scorer behind one visitor hop (the oracle the
/// `spp predict --matcher naive` arm runs).
struct NaivePredict<'a> {
    model: &'a SparsePatternModel,
}

impl SubstrateVisitor for NaivePredict<'_> {
    type Out = Vec<f64>;
    fn visit<S: RegistrySubstrate>(self, db: &S, _y: &[f64]) -> Self::Out {
        self.model.predict(db)
    }
}

/// `spp lambda-max`'s visitor, test-local.
struct LmV {
    task: Task,
    maxpat: usize,
}

impl SubstrateVisitor for LmV {
    type Out = LambdaMax;
    fn visit<S: RegistrySubstrate>(self, db: &S, y: &[f64]) -> Self::Out {
        lambda_max(db, y, self.task, self.maxpat, 1)
    }
}

/// `spp mine`'s visitor, test-local.
struct MineV {
    maxpat: usize,
}

impl SubstrateVisitor for MineV {
    type Out = Vec<(usize, String)>;
    fn visit<S: RegistrySubstrate>(self, db: &S, _y: &[f64]) -> Self::Out {
        struct Collect {
            rows: Vec<(usize, String)>,
        }
        impl TreeVisitor for Collect {
            fn visit(&mut self, node: &PatternNode<'_>) -> Walk {
                self.rows
                    .push((node.support.len(), node.to_pattern().display()));
                Walk::Descend
            }
        }
        let mut c = Collect { rows: Vec::new() };
        db.traverse(self.maxpat, 1, &mut c);
        c.rows
    }
}

/// Every registered preset flows through the full visitor surface:
/// path (coordinator), fit (estimator), predict (compiled + naive,
/// bit-identical), λ_max and mine.
#[test]
fn every_preset_runs_the_whole_command_surface() {
    for info in registry::ALL {
        let scale = tiny_scale(&info);
        let cfg = tiny_cfg(2);

        // path — through the coordinator's visitor
        let r = run_experiment(&ExperimentSpec {
            dataset: info.name.into(),
            scale,
            maxpat: cfg.maxpat,
            method: Method::Spp,
            cfg,
        })
        .unwrap_or_else(|e| panic!("{}: path failed: {e:#}", info.name));
        assert_eq!(r.path.points.len(), cfg.n_lambdas, "{}", info.name);
        assert!(r.max_gap <= 2e-6, "{}: gap {}", info.name, r.max_gap);
        assert_eq!(r.task, info.task, "{}", info.name);

        // λ_max — the standalone command agrees with the path's head
        let data = registry::lookup(info.name, scale).unwrap();
        let lm = data.visit(LmV {
            task: info.task,
            maxpat: cfg.maxpat,
        });
        assert_eq!(
            lm.lambda_max.to_bits(),
            r.path.lambda_max.to_bits(),
            "{}: lambda-max drifted from the path engine",
            info.name
        );
        assert!(lm.stats.nodes > 0, "{}", info.name);

        // fit — the estimator's visitor entrypoint
        let est = SppEstimator::new(info.task)
            .maxpat(cfg.maxpat)
            .lambda_grid(cfg.n_lambdas, cfg.lambda_min_ratio);
        let fit = est
            .fit_dataset(&data)
            .unwrap_or_else(|e| panic!("{}: fit failed: {e:#}", info.name));
        assert_eq!(
            fit.path.lambda_max.to_bits(),
            r.path.lambda_max.to_bits(),
            "{}: fit_dataset diverged from run_experiment",
            info.name
        );

        // predict — serve-layer compiled matcher vs the naive oracle,
        // bit-identical final predictions
        let model = fit.model;
        let reparsed = SparsePatternModel::parse(&model.serialize().unwrap()).unwrap();
        let compiled = CompiledModel::compile_for(&reparsed, info.kind.tag()).unwrap();
        let batch = compiled.score_dataset(&data, 1).unwrap();
        let naive = data.visit(NaivePredict { model: &reparsed });
        assert_eq!(batch.scores.len(), naive.len(), "{}", info.name);
        for (s, n) in batch.scores.iter().zip(&naive) {
            assert_eq!(
                compiled.output(*s).to_bits(),
                n.to_bits(),
                "{}: compiled and naive matchers disagree",
                info.name
            );
        }

        // mine — raw traversal through the same dispatch point
        let rows = data.visit(MineV { maxpat: cfg.maxpat });
        assert!(!rows.is_empty(), "{}: mine found nothing", info.name);
        assert!(
            rows.len() as u64 >= lm.stats.nodes - lm.stats.pruned,
            "{}: mine saw fewer nodes than the screened traversal kept",
            info.name
        );
    }
}

fn shaped_path(
    dataset: &str,
    scale: f64,
    reuse_forest: bool,
    range_chunk: usize,
    threads: usize,
) -> PathResult {
    let cfg = PathConfig {
        reuse_forest,
        range_chunk,
        threads,
        ..tiny_cfg(2)
    };
    run_experiment(&ExperimentSpec {
        dataset: dataset.into(),
        scale,
        maxpat: cfg.maxpat,
        method: Method::Spp,
        cfg,
    })
    .unwrap_or_else(|e| panic!("{dataset} shape ({reuse_forest},{range_chunk},{threads}): {e:#}"))
    .path
}

fn assert_bit_identical(dataset: &str, shape: &str, a: &PathResult, b: &PathResult) {
    assert_eq!(a.lambda_max.to_bits(), b.lambda_max.to_bits(), "{dataset} {shape}");
    assert_eq!(a.points.len(), b.points.len(), "{dataset} {shape}");
    for (pa, pb) in a.points.iter().zip(&b.points) {
        assert_eq!(pa.lambda.to_bits(), pb.lambda.to_bits(), "{dataset} {shape}");
        assert_eq!(pa.b.to_bits(), pb.b.to_bits(), "{dataset} {shape} λ={}", pa.lambda);
        assert_eq!(pa.gap.to_bits(), pb.gap.to_bits(), "{dataset} {shape} λ={}", pa.lambda);
        assert_eq!(
            pa.working_size, pb.working_size,
            "{dataset} {shape} λ={}",
            pa.lambda
        );
        assert_eq!(pa.active.len(), pb.active.len(), "{dataset} {shape} λ={}", pa.lambda);
        for ((qa, wa), (qb, wb)) in pa.active.iter().zip(&pb.active) {
            assert_eq!(qa, qb, "{dataset} {shape} λ={}", pa.lambda);
            assert_eq!(wa.to_bits(), wb.to_bits(), "{dataset} {shape} λ={}", pa.lambda);
        }
    }
}

/// The `PathDriver` correctness bar: on one substrate per kind, every
/// engine shape — forest on/off × per-λ vs chunked screening × 1 vs 4
/// workers — reproduces the baseline (forest, chunk 1, sequential)
/// path bit for bit, and the driver's telemetry still tells the shapes
/// apart.
#[test]
fn every_engine_shape_is_bit_identical_to_the_baseline() {
    for dataset in ["splice", "cpdb", "synth-seq", "synth-tab"] {
        let info = registry::require_info(dataset).unwrap();
        let scale = tiny_scale(&info);
        let base = shaped_path(dataset, scale, true, 1, 1);

        for reuse in [true, false] {
            for chunk in [1usize, 4] {
                let mut per_thread = Vec::new();
                for threads in [1usize, 4] {
                    let p = shaped_path(dataset, scale, reuse, chunk, threads);
                    let shape = format!("forest={reuse} chunk={chunk} threads={threads}");
                    assert_bit_identical(dataset, &shape, &base, &p);

                    // telemetry still distinguishes the shapes
                    if chunk > 1 {
                        assert!(p.total_chunk_mine_nodes() > 0, "{dataset} {shape}");
                        assert!(p.chunk_hits() > 0, "{dataset} {shape}");
                    } else {
                        assert_eq!(p.total_chunk_mine_nodes(), 0, "{dataset} {shape}");
                        assert_eq!(p.chunk_hits(), 0, "{dataset} {shape}");
                        if reuse {
                            assert!(p.total_forest_hits() > 0, "{dataset} {shape}");
                        }
                    }
                    per_thread.push(p);
                }
                // the traversal bill is a per-shape property, not a
                // per-thread-count one
                let nodes: Vec<u64> = per_thread.iter().map(|p| p.total_nodes()).collect();
                assert_eq!(nodes[0], nodes[1], "{dataset} forest={reuse} chunk={chunk}");
            }
        }
    }
}
