//! CLI argument parsing: subcommand + flag round-trips for `spp::cli`.
//!
//! The binary's dispatch is exercised end-to-end in
//! `integration_coordinator.rs`; this suite pins the parser itself —
//! the grammar every `spp <command>` invocation goes through — against
//! the documented behaviour in `rust/src/cli.rs`.

use spp::cli::Args;

fn parse(line: &str) -> Args {
    Args::parse(line.split_whitespace().map(String::from))
}

#[test]
fn empty_argv_yields_empty_command() {
    let a = Args::parse(std::iter::empty::<String>());
    assert_eq!(a.command, "");
    assert!(a.positional.is_empty());
    assert!(!a.switch("anything"));
}

#[test]
fn every_subcommand_is_the_first_token() {
    for cmd in ["path", "lambda-max", "mine", "selftest", "datasets", "help"] {
        let a = parse(&format!("{cmd} --scale 0.5"));
        assert_eq!(a.command, cmd);
        assert_eq!(a.get_f64("scale", 1.0).unwrap(), 0.5);
    }
}

#[test]
fn path_invocation_round_trips_all_documented_flags() {
    // the `spp path` synopsis from main.rs, exercised in full
    let a = parse(
        "path --dataset cpdb --maxpat 5 --method both --lambdas 100 \
         --min-ratio 0.01 --scale 1.0 --certify --engine rust --json out.json",
    );
    assert_eq!(a.command, "path");
    assert_eq!(a.flag("dataset"), Some("cpdb"));
    assert_eq!(a.get_usize("maxpat", 0).unwrap(), 5);
    assert_eq!(a.get_or("method", "spp"), "both");
    assert_eq!(a.get_usize("lambdas", 0).unwrap(), 100);
    assert_eq!(a.get_f64("min-ratio", 0.0).unwrap(), 0.01);
    assert_eq!(a.get_f64("scale", 0.0).unwrap(), 1.0);
    assert!(a.switch("certify"));
    assert_eq!(a.get_or("engine", "xla"), "rust");
    assert_eq!(a.flag("json"), Some("out.json"));
    assert!(a.positional.is_empty());
}

#[test]
fn equals_and_space_forms_are_equivalent() {
    let spaced = parse("mine --dataset splice --maxpat 3 --top 20");
    let equals = parse("mine --dataset=splice --maxpat=3 --top=20");
    for name in ["dataset", "maxpat", "top"] {
        assert_eq!(spaced.flag(name), equals.flag(name), "flag {name}");
    }
}

#[test]
fn defaults_apply_only_when_flags_are_absent() {
    let a = parse("lambda-max --maxpat 7");
    assert_eq!(a.get_usize("maxpat", 4).unwrap(), 7);
    assert_eq!(a.get_usize("minsup", 1).unwrap(), 1);
    assert_eq!(a.get_f64("scale", 1.0).unwrap(), 1.0);
    assert_eq!(a.get_or("dataset", "splice"), "splice");
    assert!(a.flag("dataset").is_none());
}

#[test]
fn numeric_parse_errors_name_the_flag_and_value() {
    let a = parse("path --lambdas many --scale wide");
    let e = a.get_usize("lambdas", 100).unwrap_err().to_string();
    assert!(e.contains("lambdas") && e.contains("many"), "{e}");
    let e = a.get_f64("scale", 1.0).unwrap_err().to_string();
    assert!(e.contains("scale") && e.contains("wide"), "{e}");
    // a bad value behind an unread flag must not affect other lookups
    assert_eq!(a.get_usize("maxpat", 4).unwrap(), 4);
}

#[test]
fn switch_answers_for_both_bare_and_valued_forms() {
    let bare = parse("path --certify");
    assert!(bare.switch("certify"));
    assert!(bare.flag("certify").is_none());
    // a switch that swallowed a value still counts as set (documented
    // grammar footgun, pinned in src/cli.rs unit tests too)
    let valued = parse("path --certify out.json");
    assert!(valued.switch("certify"));
    assert_eq!(valued.flag("certify"), Some("out.json"));
}

#[test]
fn negative_numbers_are_flag_values_not_flags() {
    // "-0.5" does not start with "--", so it is consumed as a value
    let a = parse("mine --scale -0.5");
    assert_eq!(a.get_f64("scale", 1.0).unwrap(), -0.5);
}

#[test]
fn certify_false_reads_as_off() {
    // regression: `--certify=false` used to count as switch-on because
    // switch() answered true whenever the flag map contained the name
    let a = parse("path --certify=false");
    assert!(!a.switch("certify"));
    assert_eq!(a.flag("certify"), Some("false"));
    let a = parse("path --certify false");
    assert!(!a.switch("certify"));
    // other values still mean on; absence means off
    assert!(parse("path --certify=true").switch("certify"));
    assert!(parse("path --certify").switch("certify"));
    assert!(!parse("path").switch("certify"));
}

#[test]
fn declared_switches_never_consume_positionals() {
    // the spp binary declares its switch set, making flag-value
    // consumption explicit rather than peek-based: a declared switch
    // consumes only boolean literals, never a positional
    let a = Args::parse_with_switches(
        "path --certify out.json --viol-tol -1e-6 --maxpat 3"
            .split_whitespace()
            .map(String::from),
        &["certify"],
        &["viol-tol", "maxpat"],
    )
    .unwrap();
    assert!(a.switch("certify"));
    assert!(a.flag("certify").is_none());
    assert_eq!(a.positional, vec!["out.json"]);
    assert_eq!(a.get_f64("viol-tol", 0.0).unwrap(), -1e-6);
    assert_eq!(a.get_usize("maxpat", 0).unwrap(), 3);
    // space-separated boolean still reads as a value (matches --certify=false)
    let a = Args::parse_with_switches(
        "path --certify false".split_whitespace().map(String::from),
        &["certify"],
        &[],
    )
    .unwrap();
    assert!(!a.switch("certify"));
}

#[test]
fn reuse_and_dynamic_screen_switches_parse_all_forms() {
    // the engine toggles added with the incremental forest, in the
    // declared-switch grammar the spp binary uses
    let switches = &["certify", "no-reuse", "dynamic-screen"];
    let flags = &["dataset", "maxpat"];
    let sw = |line: &str| {
        Args::parse_with_switches(line.split_whitespace().map(String::from), switches, flags)
            .unwrap()
    };
    // defaults: reuse on, dynamic screening on
    let a = sw("path --dataset splice");
    assert!(!a.switch("no-reuse"));
    assert!(a.flag("dynamic-screen").is_none());
    // --no-reuse turns the forest engine off; =false re-enables
    assert!(sw("path --no-reuse").switch("no-reuse"));
    assert!(!sw("path --no-reuse=false").switch("no-reuse"));
    // dynamic-screen: valued forms decide; a declared switch never
    // swallows a following non-boolean token
    let a = sw("path --dynamic-screen=false --maxpat 3");
    assert!(!a.switch("dynamic-screen"));
    let a = sw("path --dynamic-screen false --maxpat 3");
    assert!(!a.switch("dynamic-screen"));
    assert_eq!(a.get_usize("maxpat", 0).unwrap(), 3);
    let a = sw("path --dynamic-screen out.json");
    assert!(a.switch("dynamic-screen"));
    assert_eq!(a.positional, vec!["out.json"]);
}

#[test]
fn unknown_threads_style_flags_error_naming_the_flag() {
    // regression (PR 4 satellite): a typo'd `--threads`-style flag used
    // to be silently swallowed by the permissive fallback (or, in the
    // command slot, to surface as the generic "unknown command '--…'"
    // message); the declared grammar must reject it and NAME it
    let switches = &["certify", "no-reuse", "dynamic-screen"];
    let flags = &["dataset", "maxpat", "threads"];
    let parse = |line: &str| {
        Args::parse_with_switches(line.split_whitespace().map(String::from), switches, flags)
    };
    let e = parse("path --treads 4").unwrap_err().to_string();
    assert!(e.contains("--treads"), "error must name the typo'd flag: {e}");
    let e = parse("path --thread=4").unwrap_err().to_string();
    assert!(e.contains("--thread"), "{e}");
    // a declared value flag with no value is also named
    let e = parse("path --threads").unwrap_err().to_string();
    assert!(e.contains("--threads") && e.contains("value"), "{e}");
    // a flag where the command belongs is named, not reported as an
    // unknown command
    let e = parse("--threads 4 path").unwrap_err().to_string();
    assert!(e.contains("--threads") && e.contains("command"), "{e}");
    // the real spelling round-trips
    let a = parse("path --threads 4 --dataset splice").unwrap();
    assert_eq!(a.get_usize("threads", 0).unwrap(), 4);
    assert_eq!(a.flag("dataset"), Some("splice"));
}

#[test]
fn per_command_help_survives_the_strict_grammar() {
    // regression: `spp path --help` must parse under the declared
    // grammar (main.rs declares `help` as a switch and dispatches on
    // it), not die as an unknown flag
    let switches = &["certify", "dynamic-screen", "help", "no-reuse"];
    let a = Args::parse_with_switches(
        "path --help".split_whitespace().map(String::from),
        switches,
        &["dataset"],
    )
    .unwrap();
    assert_eq!(a.command, "path");
    assert!(a.switch("help"));
    // bare `--help` in the command slot also still works
    let a = Args::parse_with_switches(
        std::iter::once("--help".to_string()),
        switches,
        &["dataset"],
    )
    .unwrap();
    assert_eq!(a.command, "--help");
}

#[test]
fn repeated_flags_keep_the_last_value() {
    let a = parse("path --maxpat 3 --maxpat 9");
    assert_eq!(a.get_usize("maxpat", 0).unwrap(), 9);
}

#[test]
fn positionals_interleave_with_flags() {
    let a = parse("mine first --maxpat 2 second");
    assert_eq!(a.positional, vec!["first", "second"]);
    assert_eq!(a.get_usize("maxpat", 0).unwrap(), 2);
}

#[test]
fn main_rs_path_config_flags_round_trip() {
    // the exact flag set main.rs::path_config reads, in one line
    let a = parse("path --lambdas 10 --min-ratio 0.05 --maxpat 3 --minsup 2 --k-add 5");
    assert_eq!(a.get_usize("lambdas", 100).unwrap(), 10);
    assert_eq!(a.get_f64("min-ratio", 0.01).unwrap(), 0.05);
    assert_eq!(a.get_usize("maxpat", 4).unwrap(), 3);
    assert_eq!(a.get_usize("minsup", 1).unwrap(), 2);
    assert_eq!(a.get_usize("k-add", 1).unwrap(), 5);
    assert!(!a.switch("certify"));
}
