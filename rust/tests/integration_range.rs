//! Range-based (interval) SPP, end to end: the chunked engine
//! (`PathConfig::range_chunk > 1` — one interval-radius mine per chunk
//! of grid points, per-λ survivor sets re-derived from the stored
//! columns) must be **bit-identical** to the per-λ engine — same active
//! sets (patterns and order), same weight/intercept/gap bits, same |Â|
//! — on all three shipped substrates, in both the forest-reuse and
//! scratch configurations, at any thread count; and k-fold CV under the
//! chunked engine must pin the same best-λ index and bit-identical fold
//! losses.  On the dense splice preset at 20 λs the chunked scratch
//! engine must also traverse strictly fewer substrate nodes than per-λ
//! scratch screening (the acceptance regime; `benches/ablation_range.rs`
//! asserts the same on all three substrates at bench scale).

use spp::data::sequence::{self, SeqSynthConfig};
use spp::data::synth_graphs::{self, GraphSynthConfig};
use spp::data::synth_itemsets::{self, ItemsetSynthConfig};
use spp::mining::PatternSubstrate;
use spp::path::cv::{cross_validate, CvResult};
use spp::path::{compute_path_spp, PathConfig, PathResult};
use spp::solver::Task;

fn cfg(n_lambdas: usize, maxpat: usize, reuse: bool, chunk: usize) -> PathConfig {
    PathConfig {
        n_lambdas,
        lambda_min_ratio: 0.05,
        maxpat,
        reuse_forest: reuse,
        range_chunk: chunk,
        ..PathConfig::default()
    }
}

/// Bitwise equality of everything the solver produced (telemetry and
/// wall-clock excluded — the two engines deliberately do their
/// traversal work in different places).
fn assert_results_bitwise(a: &PathResult, b: &PathResult) {
    assert_eq!(a.lambda_max.to_bits(), b.lambda_max.to_bits());
    assert_eq!(a.points.len(), b.points.len());
    for (p, q) in a.points.iter().zip(&b.points) {
        assert_eq!(p.lambda.to_bits(), q.lambda.to_bits());
        assert_eq!(
            p.active.len(),
            q.active.len(),
            "active-set size mismatch at λ={}: {} vs {}",
            p.lambda,
            p.active.len(),
            q.active.len()
        );
        for ((pa, wa), (pb, wb)) in p.active.iter().zip(&q.active) {
            assert_eq!(pa, pb, "active pattern/order mismatch at λ={}", p.lambda);
            assert_eq!(
                wa.to_bits(),
                wb.to_bits(),
                "weight bits differ at λ={} on {}: {wa} vs {wb}",
                p.lambda,
                pa.display()
            );
        }
        assert_eq!(p.b.to_bits(), q.b.to_bits(), "intercept bits at λ={}", p.lambda);
        assert_eq!(p.gap.to_bits(), q.gap.to_bits(), "gap bits at λ={}", p.lambda);
        assert!(p.gap <= 2e-6, "uncertified λ={}", p.lambda);
        // identical Â and identical solver trajectory
        assert_eq!(p.working_size, q.working_size, "|Â| at λ={}", p.lambda);
        assert_eq!(p.cd_epochs, q.cd_epochs, "solver epochs at λ={}", p.lambda);
    }
}

/// Per-λ vs chunked on one substrate/config; returns the chunked run.
fn case<S: PatternSubstrate>(
    db: &S,
    y: &[f64],
    task: Task,
    base: &PathConfig,
    chunk: usize,
) -> PathResult {
    let mut per_lambda = *base;
    per_lambda.range_chunk = 1;
    let mut chunked = *base;
    chunked.range_chunk = chunk;
    let a = compute_path_spp(db, y, task, &per_lambda).unwrap();
    let b = compute_path_spp(db, y, task, &chunked).unwrap();
    assert_results_bitwise(&a, &b);
    // telemetry shape: only the chunked engine records chunk work
    assert_eq!(a.total_chunk_mine_nodes(), 0);
    assert_eq!(a.chunk_hits(), 0);
    assert!(b.total_chunk_mine_nodes() > 0, "chunk={chunk}: no pre-mine ran");
    assert!(b.chunk_hits() > 0, "chunk={chunk}: no λ was served from its chunk tree");
    b
}

#[test]
fn itemsets_bit_identical_both_tasks_both_engines() {
    for (seed, classify) in [(101u64, false), (102, true)] {
        let d = synth_itemsets::generate(&ItemsetSynthConfig::tiny(seed, classify));
        let task = if classify {
            Task::Classification
        } else {
            Task::Regression
        };
        for reuse in [true, false] {
            for chunk in [3usize, 64] {
                // chunk 64 > grid: the whole tail is ONE chunk — a
                // single database search serves every λ
                let b = case(&d.db, &d.y, task, &cfg(10, 3, reuse, 1), chunk);
                if chunk == 64 {
                    let leaders: Vec<_> = b
                        .points
                        .iter()
                        .filter(|p| p.reuse.chunk_mine_nodes > 0)
                        .collect();
                    assert_eq!(leaders.len(), 1, "one chunk ⇒ one pre-mine");
                }
            }
        }
    }
}

#[test]
fn graphs_bit_identical_both_engines() {
    for (seed, classify) in [(103u64, false), (104, true)] {
        let d = synth_graphs::generate(&GraphSynthConfig::tiny(seed, classify));
        let task = if classify {
            Task::Classification
        } else {
            Task::Regression
        };
        for reuse in [true, false] {
            case(&d.db, &d.db.y, task, &cfg(8, 3, reuse, 1), 3);
        }
    }
}

#[test]
fn sequences_bit_identical_both_engines() {
    for (seed, classify) in [(105u64, false), (106, true)] {
        let d = sequence::generate(&SeqSynthConfig::tiny(seed, classify));
        let task = if classify {
            Task::Classification
        } else {
            Task::Regression
        };
        for reuse in [true, false] {
            case(&d.db, &d.y, task, &cfg(8, 3, reuse, 1), 3);
        }
    }
}

#[test]
fn chunked_engine_with_certify_and_no_dynamic_screen_stays_identical() {
    let d = synth_itemsets::generate(&ItemsetSynthConfig::tiny(107, true));
    let mut c = cfg(8, 3, true, 1);
    c.certify = true;
    case(&d.db, &d.y, Task::Classification, &c, 4);
    let mut c = cfg(8, 3, false, 1);
    c.cd.dynamic_screen = false;
    case(&d.db, &d.y, Task::Classification, &c, 4);
}

#[test]
fn chunked_engine_is_bit_identical_at_any_thread_count() {
    // full bitwise equality INCLUDING telemetry between worker counts
    // of the same (chunked) engine — the parallel contract extends to
    // chunk pre-mines
    let d = synth_itemsets::generate(&ItemsetSynthConfig::tiny(108, false));
    for reuse in [true, false] {
        let mut c1 = cfg(10, 3, reuse, 4);
        c1.threads = 1;
        let mut c4 = c1;
        c4.threads = 4;
        let a = compute_path_spp(&d.db, &d.y, Task::Regression, &c1).unwrap();
        let b = compute_path_spp(&d.db, &d.y, Task::Regression, &c4).unwrap();
        assert_results_bitwise(&a, &b);
        for (p, q) in a.points.iter().zip(&b.points) {
            assert_eq!(p.stats, q.stats, "node counts at λ={}", p.lambda);
            assert_eq!(p.reuse, q.reuse, "reuse telemetry at λ={}", p.lambda);
        }
    }
}

#[test]
fn chunked_scratch_strictly_cheaper_on_preset_at_twenty_lambdas() {
    // the acceptance-criterion regime: dense paper-shaped preset,
    // n_lambdas >= 20 — chunked screening must beat per-λ screening on
    // substrate node counts while staying bit-identical
    let data = spp::data::registry::lookup("splice", 0.08).unwrap();
    let spp::data::registry::Dataset::Itemsets(t) = &data else {
        unreachable!()
    };
    let per_lambda =
        compute_path_spp(&t.db, &t.y, Task::Classification, &cfg(20, 3, false, 1)).unwrap();
    let chunked =
        compute_path_spp(&t.db, &t.y, Task::Classification, &cfg(20, 3, false, 5)).unwrap();
    assert_results_bitwise(&per_lambda, &chunked);
    assert!(
        chunked.total_nodes() < per_lambda.total_nodes(),
        "chunked screening must traverse strictly fewer nodes: {} vs {}",
        chunked.total_nodes(),
        per_lambda.total_nodes()
    );
}

/// 9:1 imbalanced ±1 labels over `n` records (deterministic).
fn imbalanced_labels(n: usize) -> Vec<f64> {
    (0..n).map(|i| if i % 10 == 0 { -1.0 } else { 1.0 }).collect()
}

fn assert_cv_bitwise(a: &CvResult, b: &CvResult) {
    assert_eq!(a.best, b.best, "best-λ index differs");
    assert_eq!(a.points.len(), b.points.len());
    for (p, q) in a.points.iter().zip(&b.points) {
        assert_eq!(p.lambda_frac.to_bits(), q.lambda_frac.to_bits());
        assert_eq!(p.mean_loss.to_bits(), q.mean_loss.to_bits());
        assert_eq!(p.mean_active.to_bits(), q.mean_active.to_bits());
        assert_eq!(p.fold_losses.len(), q.fold_losses.len());
        for (x, y) in p.fold_losses.iter().zip(&q.fold_losses) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}

/// Classification CV with imbalanced labels on one substrate, swept
/// over engine (per-λ vs chunked) and worker count (1 vs 4): every
/// combination must pin the same best-λ index and bit-identical fold
/// losses, and every loss must be a real error rate (no degenerate
/// fold ever collapses).
fn cv_case<S: PatternSubstrate + Sync>(db: &S, y: &[f64], n_lambdas: usize, maxpat: usize) {
    let folds = 4;
    let seed = 9;
    let mut runs: Vec<CvResult> = Vec::new();
    for chunk in [1usize, 3] {
        for threads in [1usize, 4] {
            let mut c = cfg(n_lambdas, maxpat, true, chunk);
            c.threads = threads;
            let cv = cross_validate(db, y, Task::Classification, &c, folds, seed).unwrap();
            for p in &cv.points {
                assert_eq!(p.fold_losses.len(), folds);
                for &l in &p.fold_losses {
                    assert!((0.0..=1.0).contains(&l), "loss {l} is not an error rate");
                }
            }
            runs.push(cv);
        }
    }
    for other in &runs[1..] {
        assert_cv_bitwise(&runs[0], other);
    }
}

#[test]
fn imbalanced_cv_pins_best_lambda_itemsets() {
    let d = synth_itemsets::generate(&ItemsetSynthConfig::tiny(110, true));
    cv_case(&d.db, &imbalanced_labels(d.y.len()), 6, 2);
}

#[test]
fn imbalanced_cv_pins_best_lambda_graphs() {
    let d = synth_graphs::generate(&GraphSynthConfig::tiny(111, true));
    cv_case(&d.db, &imbalanced_labels(d.db.y.len()), 4, 2);
}

#[test]
fn imbalanced_cv_pins_best_lambda_sequences() {
    let d = sequence::generate(&SeqSynthConfig::tiny(112, true));
    cv_case(&d.db, &imbalanced_labels(d.y.len()), 4, 2);
}
