//! The substrate API, tested end-to-end on all three substrates.
//!
//! The paper's Theorem 2 promises that SPP screening is *safe*: the
//! screened path must reach exactly the optima the exhaustive
//! constraint-generation baseline reaches.  The property test here
//! asserts that promise in its strongest checkable form, through the
//! open `PatternSubstrate` trait only — the same generic code runs the
//! item-set, graph and sequence instances:
//!
//! * both paths are gap-certified at every λ;
//! * `(‖w‖₁, b)` agree at every λ (unique at the optimum);
//! * fitted responses agree on every record (unique at the optimum);
//! * **active sets agree**: merging weights by support column (two
//!   patterns with the same column are the same feature), every
//!   column's total weight matches across methods to solver tolerance —
//!   so neither method reports a substantial pattern the other lacks.
//!
//! Support columns are recomputed through `S::matches`, which doubles
//! as a miner-vs-matcher consistency check on every active pattern.

use std::collections::{BTreeMap, BTreeSet};

use spp::data::sequence::{self, SeqSynthConfig};
use spp::data::synth_graphs::{self, GraphSynthConfig};
use spp::data::synth_itemsets::{self, ItemsetSynthConfig};
use spp::mining::{Pattern, PatternNode, PatternSubstrate, Walk};
use spp::model::SparsePatternModel;
use spp::path::{compute_path_boosting, compute_path_spp, PathConfig, PathPoint};
use spp::solver::Task;
use spp::testutil::oracle;

fn cfg(n_lambdas: usize, maxpat: usize) -> PathConfig {
    PathConfig {
        n_lambdas,
        lambda_min_ratio: 0.05,
        maxpat,
        ..PathConfig::default()
    }
}

/// Support column of `pat`, recomputed independently of the miners
/// through the substrate's matcher.
fn support_by_matcher<S: PatternSubstrate>(db: &S, pat: &Pattern) -> Vec<u32> {
    (0..db.n_records())
        .filter(|&i| S::matches(pat, db.record(i)))
        .map(|i| i as u32)
        .collect()
}

/// Active weights merged by support column (identical columns are the
/// same feature; the restricted solver's weight split among them is
/// arbitrary, their sum is not).
fn merged_weights<S: PatternSubstrate>(
    db: &S,
    point: &PathPoint,
) -> BTreeMap<Vec<u32>, f64> {
    let mut m: BTreeMap<Vec<u32>, f64> = BTreeMap::new();
    for (pat, w) in &point.active {
        *m.entry(support_by_matcher(db, pat)).or_insert(0.0) += w;
    }
    m
}

/// The Theorem-2 agreement property for one instance.
fn assert_spp_and_boosting_active_sets_agree<S: PatternSubstrate>(
    db: &S,
    y: &[f64],
    task: Task,
    c: &PathConfig,
) {
    let spp = compute_path_spp(db, y, task, c).unwrap();
    let boost = compute_path_boosting(db, y, task, c).unwrap();
    assert_eq!(spp.points.len(), boost.points.len());
    assert!((spp.lambda_max - boost.lambda_max).abs() < 1e-9);

    for (a, b) in spp.points.iter().zip(&boost.points) {
        assert!(a.gap <= 2e-6 && b.gap <= 2e-6, "uncertified λ={}", a.lambda);
        let l1a: f64 = a.active.iter().map(|(_, w)| w.abs()).sum();
        let l1b: f64 = b.active.iter().map(|(_, w)| w.abs()).sum();
        let scale = 1.0 + l1a.abs();
        assert!(
            (l1a - l1b).abs() < 1e-3 * scale,
            "‖w‖₁ mismatch at λ={}: {l1a} vs {l1b}",
            a.lambda
        );
        assert!((a.b - b.b).abs() < 2e-3, "b mismatch at λ={}", a.lambda);

        // active sets merged by support column: every column carries
        // the same total weight in both methods (up to the solvers'
        // 1e-6 gap tolerance, loosened to a safe margin)
        let wa = merged_weights(db, a);
        let wb = merged_weights(db, b);
        let keys: BTreeSet<&Vec<u32>> = wa.keys().chain(wb.keys()).collect();
        for k in keys {
            let va = wa.get(k).copied().unwrap_or(0.0);
            let vb = wb.get(k).copied().unwrap_or(0.0);
            assert!(
                (va - vb).abs() < 2e-2 * scale,
                "active-set mismatch at λ={}: column {:?} has weight {va} (spp) vs {vb} (boosting)",
                a.lambda,
                k
            );
        }

        // fitted responses (unique at the optimum) agree record-wise
        let ma = SparsePatternModel::from_path_point(task, a);
        let mb = SparsePatternModel::from_path_point(task, b);
        for i in 0..db.n_records() {
            let sa = ma.score::<S>(db.record(i));
            let sb = mb.score::<S>(db.record(i));
            assert!(
                (sa - sb).abs() < 1e-2 * scale,
                "fitted score mismatch at λ={} record {i}: {sa} vs {sb}",
                a.lambda
            );
        }
    }
}

#[test]
fn active_sets_agree_itemsets() {
    for (seed, classify) in [(21u64, false), (22, true)] {
        let d = synth_itemsets::generate(&ItemsetSynthConfig::tiny(seed, classify));
        let task = if classify {
            Task::Classification
        } else {
            Task::Regression
        };
        assert_spp_and_boosting_active_sets_agree(&d.db, &d.y, task, &cfg(8, 3));
    }
}

#[test]
fn active_sets_agree_sequences() {
    for (seed, classify) in [(21u64, false), (22, true)] {
        let d = sequence::generate(&SeqSynthConfig::tiny(seed, classify));
        let task = if classify {
            Task::Classification
        } else {
            Task::Regression
        };
        assert_spp_and_boosting_active_sets_agree(&d.db, &d.y, task, &cfg(8, 3));
    }
}

#[test]
fn active_sets_agree_graphs() {
    let d = synth_graphs::generate(&GraphSynthConfig::tiny(43, false));
    assert_spp_and_boosting_active_sets_agree(&d.db, &d.db.y, Task::Regression, &cfg(6, 3));
}

/// The PrefixSpan miner against the brute-force oracle on seeded
/// instances: same pattern set, same supports.
#[test]
fn prefixspan_matches_oracle_on_seeded_instances() {
    for seed in [1u64, 2, 3] {
        let d = sequence::generate(&SeqSynthConfig::tiny(seed, false));
        for maxpat in [2usize, 3] {
            let mut mined: BTreeMap<Vec<u32>, Vec<u32>> = BTreeMap::new();
            let mut v = |n: &PatternNode<'_>| {
                let Pattern::Sequence(s) = n.to_pattern() else {
                    unreachable!()
                };
                assert!(
                    mined.insert(s, n.support.to_vec()).is_none(),
                    "duplicate pattern (seed {seed})"
                );
                Walk::Descend
            };
            d.db.traverse(maxpat, 1, &mut v);
            let brute = oracle::all_sequences(&d.db, maxpat);
            assert_eq!(mined, brute, "seed {seed} maxpat {maxpat}");
        }
    }
}

/// A sequence model mined from a real path round-trips through the
/// text format and predicts identically after the round trip.
#[test]
fn sequence_model_round_trips_through_text_format() {
    let d = sequence::generate(&SeqSynthConfig::tiny(7, false));
    let path = compute_path_spp(&d.db, &d.y, Task::Regression, &cfg(6, 2)).unwrap();
    let point = path.points.last().unwrap();
    assert!(
        !point.active.is_empty(),
        "smallest-λ model should have active sequence patterns"
    );
    let model = SparsePatternModel::from_path_point(Task::Regression, point);
    let back = SparsePatternModel::parse(&model.serialize().unwrap()).unwrap();
    assert_eq!(model, back);
    assert_eq!(model.predict(&d.db), back.predict(&d.db));
    // and the codec really used the sequence tag
    assert!(model.serialize().unwrap().lines().skip(1).all(|l| l.starts_with("S ")));
}

/// `synth-seq` flows through the registry + coordinator exactly like
/// the paper's presets (the `spp path --dataset synth-seq` path).
#[test]
fn sequence_dataset_runs_through_coordinator() {
    use spp::coordinator::{run_experiment, ExperimentSpec, Method};
    let mut results = Vec::new();
    for method in [Method::Spp, Method::Boosting] {
        let r = run_experiment(&ExperimentSpec {
            dataset: "synth-seq".into(),
            scale: 0.1,
            maxpat: 2,
            method,
            cfg: PathConfig {
                n_lambdas: 5,
                lambda_min_ratio: 0.1,
                ..PathConfig::default()
            },
        })
        .unwrap();
        assert!(r.max_gap <= 2e-6, "{method:?} gap {}", r.max_gap);
        assert!(r.traverse_nodes > 0);
        assert_eq!(r.task, Task::Classification);
        results.push(r);
    }
    for (a, b) in results[0].path.points.iter().zip(&results[1].path.points) {
        let l1a: f64 = a.active.iter().map(|(_, w)| w.abs()).sum();
        let l1b: f64 = b.active.iter().map(|(_, w)| w.abs()).sum();
        assert!((l1a - l1b).abs() < 1e-3 * (1.0 + l1a), "λ={}", a.lambda);
    }
}
