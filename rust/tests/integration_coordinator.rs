//! Coordinator + CLI integration: experiment grids through the worker
//! pool, report integrity, and the `spp` binary end to end.

use std::process::Command;

use spp::coordinator::{report, Pool, ExperimentSpec, Method};
use spp::path::PathConfig;

fn spec(dataset: &str, maxpat: usize, method: Method) -> ExperimentSpec {
    ExperimentSpec {
        dataset: dataset.into(),
        scale: 0.05,
        maxpat,
        method,
        cfg: PathConfig {
            n_lambdas: 4,
            lambda_min_ratio: 0.2,
            maxpat,
            // the grid test below compares SPP vs boosting NODE COUNTS,
            // which is a per-λ-engine property — chunking moves the
            // traversal bill (its equivalence lives in
            // tests/integration_range.rs)
            range_chunk: 1,
            ..PathConfig::default()
        },
    }
}

#[test]
fn figure_style_grid_runs_in_pool() {
    let mut specs = Vec::new();
    for ds in ["splice", "cpdb"] {
        for maxpat in [2usize, 3] {
            for m in [Method::Spp, Method::Boosting] {
                specs.push(spec(ds, maxpat, m));
            }
        }
    }
    let results = Pool::new(2).run(specs);
    assert_eq!(results.len(), 8);
    for r in &results {
        let r = r.as_ref().expect("experiment failed");
        assert!(r.max_gap <= 2e-6, "{}: gap {}", r.spec.dataset, r.max_gap);
        assert!(r.traverse_nodes > 0);
        assert!(!report::time_row(r).is_empty());
        assert!(!report::nodes_row(r).is_empty());
    }
    // pairwise: SPP nodes <= boosting nodes on the same workload
    for pair in results.chunks(2) {
        let (s, b) = (pair[0].as_ref().unwrap(), pair[1].as_ref().unwrap());
        assert_eq!(s.spec.method, Method::Spp);
        assert_eq!(b.spec.method, Method::Boosting);
        assert!(
            s.traverse_nodes <= b.traverse_nodes,
            "{} maxpat={}: {} > {}",
            s.spec.dataset,
            s.spec.maxpat,
            s.traverse_nodes,
            b.traverse_nodes
        );
    }
}

#[test]
fn single_worker_pool_matches_parallel_pool() {
    let specs = vec![spec("splice", 2, Method::Spp)];
    let seq = Pool::new(1).run(specs.clone());
    let par = Pool::new(4).run(specs);
    let (a, b) = (seq[0].as_ref().unwrap(), par[0].as_ref().unwrap());
    assert_eq!(a.traverse_nodes, b.traverse_nodes);
    assert_eq!(a.final_active, b.final_active);
    assert!((a.lambda_max - b.lambda_max).abs() < 1e-12);
}

fn run_cli(args: &[&str]) -> (String, String, bool) {
    let out = Command::new(env!("CARGO_BIN_EXE_spp"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn spp");
    (
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
        out.status.success(),
    )
}

#[test]
fn cli_datasets_lists_all_presets() {
    let (stdout, _, ok) = run_cli(&["datasets"]);
    assert!(ok);
    for name in [
        "cpdb",
        "mutagenicity",
        "bergstrom",
        "karthikeyan",
        "splice",
        "a9a",
        "dna",
        "protein",
    ] {
        assert!(stdout.contains(name), "missing {name} in:\n{stdout}");
    }
}

#[test]
fn cli_lambda_max_reports_value() {
    let (stdout, stderr, ok) = run_cli(&[
        "lambda-max", "--dataset", "splice", "--scale", "0.05", "--maxpat", "2",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("lambda_max="), "{stdout}");
    assert!(stdout.contains("nodes="));
}

#[test]
fn cli_path_json_output() {
    let tmp = std::env::temp_dir().join(format!("spp-cli-{}.json", std::process::id()));
    let (stdout, stderr, ok) = run_cli(&[
        "path", "--dataset", "splice", "--scale", "0.05", "--maxpat", "2",
        "--lambdas", "4", "--min-ratio", "0.2", "--json", tmp.to_str().unwrap(),
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("speedup"), "{stdout}");
    let json = std::fs::read_to_string(&tmp).unwrap();
    assert_eq!(json.lines().count(), 2); // spp + boosting
    for line in json.lines() {
        assert!(line.starts_with('{') && line.ends_with('}'));
        assert!(line.contains("\"per_lambda\""));
    }
    std::fs::remove_file(&tmp).ok();
}

#[test]
fn cli_cv_selects_a_lambda() {
    let (stdout, stderr, ok) = run_cli(&[
        "cv", "--dataset", "splice", "--scale", "0.05", "--maxpat", "2",
        "--lambdas", "4", "--min-ratio", "0.2", "--folds", "3", "--range-chunk", "2",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("chunk=2"), "{stdout}");
    assert!(stdout.contains("<- best"), "{stdout}");
    assert!(stdout.contains("best: index"), "{stdout}");
}

#[test]
fn cli_rejects_unknown_commands_and_datasets() {
    let (_, stderr, ok) = run_cli(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("unknown command"));
    let (_, stderr, ok) = run_cli(&["path", "--dataset", "nope"]);
    assert!(!ok);
    assert!(stderr.contains("unknown dataset"));
}

#[test]
fn cli_mine_lists_patterns() {
    let (stdout, stderr, ok) = run_cli(&[
        "mine", "--dataset", "cpdb", "--scale", "0.03", "--maxpat", "2", "--top", "5",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("patterns"));
    assert!(stdout.contains("support="));
}
