//! The tabular-rule substrate, tested end-to-end.
//!
//! RuleFit-style rules — conjunctions of threshold predicates over
//! numeric features — are the fourth `PatternSubstrate`.  The per-node
//! SPPC bound is computed from `node.support` alone, so the generic
//! screening machinery applies to the rule-refinement lattice without
//! any rule-specific screening code (Kato-style meta safe screening).
//! This file pins the substrate's contracts:
//!
//! * the miner enumerates exactly the canonical rule set the
//!   brute-force oracle does, with identical supports;
//! * SPP screening visits **strictly fewer** nodes than the unpruned
//!   enumeration, with a nonzero pruned count — the whole point of the
//!   per-node bound;
//! * SPP and boosting agree on the optimum (the Theorem-2 property);
//! * paths are **bit-identical** across threads {1, 4}, forest-reuse
//!   vs from-scratch, sparse vs hybrid columns, and chunked vs per-λ
//!   screening;
//! * `synth-tab` flows through the registry + coordinator like every
//!   other preset, and fitted rule models round-trip through the text
//!   format.

use std::collections::{BTreeMap, BTreeSet};

use spp::columns::ColumnLayout;
use spp::data::tabular::{self, TabSynthConfig};
use spp::mining::rulefit::predicate_universe;
use spp::mining::{Counting, Pattern, PatternNode, PatternSubstrate, TreeVisitor, Walk};
use spp::model::SparsePatternModel;
use spp::path::{compute_path_boosting, compute_path_spp, PathConfig, PathPoint, PathResult};
use spp::screening::lambda_max::lambda_max;
use spp::screening::sppc::SppScreen;
use spp::screening::SupportPool;
use spp::solver::dual::safe_radius;
use spp::solver::problem::{dual_value, primal_value};
use spp::solver::Task;
use spp::testutil::oracle;

fn cfg(n_lambdas: usize, maxpat: usize) -> PathConfig {
    PathConfig {
        n_lambdas,
        lambda_min_ratio: 0.05,
        maxpat,
        ..PathConfig::default()
    }
}

/// The miner against the brute-force oracle on seeded instances: same
/// canonical rule set, same supports.
#[test]
fn rule_miner_matches_oracle_on_seeded_instances() {
    for seed in [1u64, 2, 3] {
        let d = tabular::generate(&TabSynthConfig::tiny(seed, false));
        let preds = predicate_universe(&d.db);
        assert!(!preds.is_empty());
        for maxpat in [1usize, 2] {
            let mut mined = BTreeMap::new();
            let mut v = |n: &PatternNode<'_>| {
                let Pattern::Rule(r) = n.to_pattern() else {
                    unreachable!()
                };
                assert!(
                    mined.insert(r, n.support.to_vec()).is_none(),
                    "duplicate rule (seed {seed})"
                );
                Walk::Descend
            };
            d.db.traverse(maxpat, 1, &mut v);
            let brute = oracle::all_rules(&d.db, maxpat, 1, &preds);
            assert_eq!(mined, brute, "seed {seed} maxpat {maxpat}");
        }
    }
}

/// Visitor that enumerates the whole tree — the unpruned baseline.
struct Full;

impl TreeVisitor for Full {
    fn visit(&mut self, _: &PatternNode<'_>) -> Walk {
        Walk::Descend
    }
}

/// SPP screening on the rule tree does strictly less work than the
/// unpruned enumeration: fewer visited nodes, nonzero pruned subtrees.
#[test]
fn screening_prunes_rule_tree_strictly() {
    let d = tabular::generate(&TabSynthConfig::tiny(11, false));
    let maxpat = 2;
    let task = Task::Regression;

    let mut every = Full;
    let mut full = Counting::new(&mut every);
    d.db.traverse(maxpat, 1, &mut full);
    assert!(full.stats.nodes > 100, "tree too small to be a meaningful baseline");

    // The path's state right after λ_max: w = 0, θ = slack0 / λ_max —
    // exactly how the path engine seeds its first screening pass.
    let lm = lambda_max(&d.db, &d.y, task, maxpat, 1);
    let lam = 0.9 * lm.lambda_max;
    let theta: Vec<f64> = lm.slack0.iter().map(|&s| s / lm.lambda_max).collect();
    let primal = primal_value(&lm.slack0, 0.0, lam);
    let dualv = dual_value(task, &theta, &d.y, lam);
    let radius = safe_radius(primal, dualv, lam);
    let mut pool = SupportPool::new();
    let mut screen = SppScreen::new(task, &d.y, &theta, radius, &mut pool);
    let mut counting = Counting::new(&mut screen);
    d.db.traverse(maxpat, 1, &mut counting);

    assert!(counting.stats.pruned > 0, "SPPC pruned no rule subtree");
    assert!(
        counting.stats.nodes < full.stats.nodes,
        "screened traversal visited {} nodes, unpruned enumeration {}",
        counting.stats.nodes,
        full.stats.nodes
    );
}

/// Support column of `pat`, recomputed independently of the miner
/// through the substrate's matcher.
fn support_by_matcher(db: &tabular::TabularData, pat: &Pattern) -> Vec<u32> {
    (0..db.n_records())
        .filter(|&i| tabular::TabularData::matches(pat, db.record(i)))
        .map(|i| i as u32)
        .collect()
}

/// Active weights merged by support column (identical columns are the
/// same feature; the weight split among them is arbitrary).
fn merged_weights(db: &tabular::TabularData, point: &PathPoint) -> BTreeMap<Vec<u32>, f64> {
    let mut m: BTreeMap<Vec<u32>, f64> = BTreeMap::new();
    for (pat, w) in &point.active {
        *m.entry(support_by_matcher(db, pat)).or_insert(0.0) += w;
    }
    m
}

/// The Theorem-2 agreement property on tabular data: the screened SPP
/// path reaches exactly the optima the boosting baseline reaches.
#[test]
fn active_sets_agree_spp_vs_boosting() {
    for (seed, classify) in [(21u64, false), (22, true)] {
        let d = tabular::generate(&TabSynthConfig::tiny(seed, classify)).labeled();
        let task = if classify {
            Task::Classification
        } else {
            Task::Regression
        };
        let c = cfg(8, 2);
        let spp = compute_path_spp(&d.db, &d.y, task, &c).unwrap();
        let boost = compute_path_boosting(&d.db, &d.y, task, &c).unwrap();
        assert_eq!(spp.points.len(), boost.points.len());
        assert!((spp.lambda_max - boost.lambda_max).abs() < 1e-9);
        for (a, b) in spp.points.iter().zip(&boost.points) {
            assert!(a.gap <= 2e-6 && b.gap <= 2e-6, "uncertified λ={}", a.lambda);
            let l1a: f64 = a.active.iter().map(|(_, w)| w.abs()).sum();
            let l1b: f64 = b.active.iter().map(|(_, w)| w.abs()).sum();
            let scale = 1.0 + l1a.abs();
            assert!(
                (l1a - l1b).abs() < 1e-3 * scale,
                "‖w‖₁ mismatch at λ={}: {l1a} vs {l1b}",
                a.lambda
            );
            assert!((a.b - b.b).abs() < 2e-3, "b mismatch at λ={}", a.lambda);
            let wa = merged_weights(&d.db, a);
            let wb = merged_weights(&d.db, b);
            let keys: BTreeSet<&Vec<u32>> = wa.keys().chain(wb.keys()).collect();
            for k in keys {
                let va = wa.get(k).copied().unwrap_or(0.0);
                let vb = wb.get(k).copied().unwrap_or(0.0);
                assert!(
                    (va - vb).abs() < 2e-2 * scale,
                    "active-set mismatch at λ={}: column {:?} has {va} (spp) vs {vb} (boosting)",
                    a.lambda,
                    k
                );
            }
        }
    }
}

/// Bitwise path equality on the optimization outputs (telemetry such
/// as node counts legitimately differs across engine configurations).
fn assert_results_bitwise(a: &PathResult, b: &PathResult) {
    assert_eq!(a.lambda_max.to_bits(), b.lambda_max.to_bits());
    assert_eq!(a.points.len(), b.points.len());
    for (p, q) in a.points.iter().zip(&b.points) {
        assert_eq!(p.lambda.to_bits(), q.lambda.to_bits());
        assert_eq!(p.active.len(), q.active.len(), "active-set size at λ={}", p.lambda);
        for ((pa, wa), (pb, wb)) in p.active.iter().zip(&q.active) {
            assert_eq!(pa, pb, "active pattern/order mismatch at λ={}", p.lambda);
            assert_eq!(
                wa.to_bits(),
                wb.to_bits(),
                "weight bits differ at λ={} on {}",
                p.lambda,
                pa.display()
            );
        }
        assert_eq!(p.b.to_bits(), q.b.to_bits(), "intercept bits at λ={}", p.lambda);
        assert_eq!(p.gap.to_bits(), q.gap.to_bits(), "gap bits at λ={}", p.lambda);
        assert!(p.gap <= 2e-6, "uncertified λ={}", p.lambda);
    }
}

/// The engine-equivalence contract on the rule substrate: bit-identical
/// paths across threads {1, 4} × forest/scratch × sparse/hybrid
/// columns × chunked/per-λ screening — 16 configurations against one
/// baseline.
#[test]
fn paths_bit_identical_across_engine_configurations() {
    let d = tabular::generate(&TabSynthConfig::tiny(31, true)).labeled();
    let task = Task::Classification;
    let mut base_cfg = cfg(8, 2);
    base_cfg.threads = 1;
    base_cfg.reuse_forest = false;
    base_cfg.range_chunk = 1;
    base_cfg.columns = Some(ColumnLayout::Sparse);
    let base = compute_path_spp(&d.db, &d.y, task, &base_cfg).unwrap();
    assert!(
        base.points.iter().any(|p| !p.active.is_empty()),
        "trivial path would make bit-identity vacuous"
    );

    for threads in [1usize, 4] {
        for reuse in [false, true] {
            for columns in [ColumnLayout::Sparse, ColumnLayout::Hybrid] {
                for range_chunk in [1usize, 4] {
                    let mut c = base_cfg;
                    c.threads = threads;
                    c.reuse_forest = reuse;
                    c.columns = Some(columns);
                    c.range_chunk = range_chunk;
                    let path = compute_path_spp(&d.db, &d.y, task, &c).unwrap();
                    assert_results_bitwise(&base, &path);
                }
            }
        }
    }
}

/// `synth-tab` flows through the registry + coordinator exactly like
/// the paper's presets (the `spp path --dataset synth-tab` path).
#[test]
fn tabular_dataset_runs_through_coordinator() {
    use spp::coordinator::{run_experiment, ExperimentSpec, Method};
    let mut results = Vec::new();
    for method in [Method::Spp, Method::Boosting] {
        let r = run_experiment(&ExperimentSpec {
            dataset: "synth-tab".into(),
            scale: 0.15,
            maxpat: 2,
            method,
            cfg: PathConfig {
                n_lambdas: 5,
                lambda_min_ratio: 0.1,
                ..PathConfig::default()
            },
        })
        .unwrap();
        assert!(r.max_gap <= 2e-6, "{method:?} gap {}", r.max_gap);
        assert!(r.traverse_nodes > 0);
        assert_eq!(r.task, Task::Classification);
        results.push(r);
    }
    for (a, b) in results[0].path.points.iter().zip(&results[1].path.points) {
        let l1a: f64 = a.active.iter().map(|(_, w)| w.abs()).sum();
        let l1b: f64 = b.active.iter().map(|(_, w)| w.abs()).sum();
        assert!((l1a - l1b).abs() < 1e-3 * (1.0 + l1a), "λ={}", a.lambda);
    }
}

/// A rule model mined from a real path round-trips through the text
/// format and predicts identically after the round trip.
#[test]
fn rule_model_round_trips_through_text_format() {
    let d = tabular::generate(&TabSynthConfig::tiny(7, false)).labeled();
    let path = compute_path_spp(&d.db, &d.y, Task::Regression, &cfg(6, 2)).unwrap();
    let point = path.points.last().unwrap();
    assert!(
        !point.active.is_empty(),
        "smallest-λ model should have active rule patterns"
    );
    let model = SparsePatternModel::from_path_point(Task::Regression, point);
    let back = SparsePatternModel::parse(&model.serialize().unwrap()).unwrap();
    assert_eq!(model, back);
    assert_eq!(model.predict(&d.db), back.predict(&d.db));
    // and the codec really used the rule tag, with space-free bodies
    for line in model.serialize().unwrap().lines().skip(1) {
        assert!(line.starts_with("R "), "non-rule term line: {line}");
        assert_eq!(line.splitn(3, ' ').count(), 3, "body must be space-free: {line}");
    }
}
