//! The incremental screening forest's safety contract, end-to-end: the
//! forest-reuse path must be **equivalent** to the from-scratch path —
//! identical per-λ active sets (patterns and order), weights to 1e-9,
//! certified gaps at tolerance — while doing strictly less substrate
//! work.  Property-tested through the open `PatternSubstrate` trait on
//! all three shipped substrates (item-sets, graphs, sequences).

use spp::data::sequence::{self, SeqSynthConfig};
use spp::data::synth_graphs::{self, GraphSynthConfig};
use spp::data::synth_itemsets::{self, ItemsetSynthConfig};
use spp::mining::PatternSubstrate;
use spp::path::{compute_path_spp, PathConfig, PathResult};
use spp::solver::Task;

fn cfg(n_lambdas: usize, maxpat: usize) -> PathConfig {
    PathConfig {
        n_lambdas,
        lambda_min_ratio: 0.05,
        maxpat,
        // this suite pins the per-λ engine: its assertions describe the
        // exact forest-vs-scratch telemetry shape (zero reuse in
        // scratch mode, node accounting); the chunked engine's
        // equivalence has its own suite, tests/integration_range.rs
        range_chunk: 1,
        ..PathConfig::default()
    }
}

/// Forest-mode path ≡ scratch-mode path, point by point.
fn assert_paths_equivalent(forest: &PathResult, scratch: &PathResult) {
    assert_eq!(forest.points.len(), scratch.points.len());
    assert_eq!(forest.lambda_max, scratch.lambda_max);
    for (f, s) in forest.points.iter().zip(&scratch.points) {
        assert_eq!(f.lambda, s.lambda);
        assert!(f.gap <= 2e-6 && s.gap <= 2e-6, "uncertified λ={}", f.lambda);
        assert_eq!(
            f.active.len(),
            s.active.len(),
            "active-set size mismatch at λ={}: {} vs {}",
            f.lambda,
            f.active.len(),
            s.active.len()
        );
        for ((pf, wf), (ps, ws)) in f.active.iter().zip(&s.active) {
            assert_eq!(pf, ps, "active pattern/order mismatch at λ={}", f.lambda);
            assert!(
                (wf - ws).abs() <= 1e-9,
                "weight mismatch at λ={} on {}: {wf} vs {ws}",
                f.lambda,
                pf.display()
            );
        }
        assert!((f.b - s.b).abs() <= 1e-9, "intercept mismatch at λ={}", f.lambda);
        // scratch mode must report zero reuse; forest mode records it
        assert_eq!(s.reuse.forest_hits, 0);
        assert_eq!(s.reuse.reopened, 0);
    }
}

fn case<S: PatternSubstrate>(db: &S, y: &[f64], task: Task, c: &PathConfig) {
    let mut forest_cfg = *c;
    forest_cfg.reuse_forest = true;
    let mut scratch_cfg = *c;
    scratch_cfg.reuse_forest = false;
    let forest = compute_path_spp(db, y, task, &forest_cfg).unwrap();
    let scratch = compute_path_spp(db, y, task, &scratch_cfg).unwrap();
    assert_paths_equivalent(&forest, &scratch);
    assert!(
        forest.total_nodes() <= scratch.total_nodes(),
        "forest traversed more: {} vs {}",
        forest.total_nodes(),
        scratch.total_nodes()
    );
    assert!(forest.total_forest_hits() > 0, "forest engine never reused a node");
}

#[test]
fn forest_equals_scratch_itemsets_both_tasks() {
    for (seed, classify) in [(61u64, false), (62, true), (63, false)] {
        let d = synth_itemsets::generate(&ItemsetSynthConfig::tiny(seed, classify));
        let task = if classify {
            Task::Classification
        } else {
            Task::Regression
        };
        case(&d.db, &d.y, task, &cfg(10, 3));
    }
}

#[test]
fn forest_equals_scratch_graphs() {
    for (seed, classify) in [(64u64, false), (65, true)] {
        let d = synth_graphs::generate(&GraphSynthConfig::tiny(seed, classify));
        let task = if classify {
            Task::Classification
        } else {
            Task::Regression
        };
        case(&d.db, &d.db.y, task, &cfg(8, 3));
    }
}

#[test]
fn forest_equals_scratch_sequences() {
    for (seed, classify) in [(66u64, false), (67, true)] {
        let d = sequence::generate(&SeqSynthConfig::tiny(seed, classify));
        let task = if classify {
            Task::Classification
        } else {
            Task::Regression
        };
        case(&d.db, &d.y, task, &cfg(8, 3));
    }
}

#[test]
fn forest_equals_scratch_with_certify_pass() {
    // the exact-feasibility rescale changes θ between λs; the forest
    // must track it identically
    let d = synth_itemsets::generate(&ItemsetSynthConfig::tiny(68, false));
    let mut c = cfg(8, 3);
    c.certify = true;
    case(&d.db, &d.y, Task::Regression, &c);
}

#[test]
fn forest_equals_scratch_without_dynamic_screening() {
    // forest reuse and solver screening are independent toggles
    let d = synth_itemsets::generate(&ItemsetSynthConfig::tiny(69, true));
    let mut c = cfg(8, 3);
    c.cd.dynamic_screen = false;
    case(&d.db, &d.y, Task::Classification, &c);
}

#[test]
fn forest_strictly_cheaper_on_preset_at_twenty_lambdas() {
    // the acceptance-criterion regime: a synth preset, n_lambdas >= 20
    let data = spp::data::registry::lookup("splice", 0.08).unwrap();
    let spp::data::registry::Dataset::Itemsets(t) = &data else {
        unreachable!()
    };
    let c = cfg(20, 3);
    let mut forest_cfg = c;
    forest_cfg.reuse_forest = true;
    let mut scratch_cfg = c;
    scratch_cfg.reuse_forest = false;
    let forest = compute_path_spp(&t.db, &t.y, Task::Classification, &forest_cfg).unwrap();
    let scratch = compute_path_spp(&t.db, &t.y, Task::Classification, &scratch_cfg).unwrap();
    assert_paths_equivalent(&forest, &scratch);
    assert!(
        forest.total_nodes() < scratch.total_nodes(),
        "forest must traverse strictly fewer nodes: {} vs {}",
        forest.total_nodes(),
        scratch.total_nodes()
    );
}

#[test]
fn dynamic_screening_freezes_columns_somewhere_on_the_path() {
    let data = spp::data::registry::lookup("splice", 0.08).unwrap();
    let spp::data::registry::Dataset::Itemsets(t) = &data else {
        unreachable!()
    };
    let path = compute_path_spp(&t.db, &t.y, Task::Classification, &cfg(20, 3)).unwrap();
    assert!(
        path.total_solver_screened() > 0,
        "dynamic screening never froze a column over a 20-λ path"
    );
    let mut off = cfg(20, 3);
    off.cd.dynamic_screen = false;
    let plain = compute_path_spp(&t.db, &t.y, Task::Classification, &off).unwrap();
    assert_eq!(plain.total_solver_screened(), 0);
    // same certified optima either way
    for (a, b) in path.points.iter().zip(&plain.points) {
        let l1a: f64 = a.active.iter().map(|(_, w)| w.abs()).sum();
        let l1b: f64 = b.active.iter().map(|(_, w)| w.abs()).sum();
        assert!((l1a - l1b).abs() < 1e-4 * (1.0 + l1a), "λ={}", a.lambda);
        assert!((a.b - b.b).abs() < 1e-4);
    }
}
