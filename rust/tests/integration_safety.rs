//! The paper's central claim, tested end-to-end: **safety**.
//!
//! For seeded random datasets small enough to enumerate exhaustively,
//! solve the *full* pattern-space problem with an independent solver at
//! high precision, then verify that
//!
//! 1. every pattern the SPP rule prunes (or the per-feature UB screens)
//!    is inactive at the true optimum (Theorem 2 / Lemma 4),
//! 2. solving restricted to Â reproduces the full optimum (Lemma 1),
//! 3. the gSpan tree and the brute-force canonical enumeration agree,
//!    so the guarantee covers graph mining too.

use spp::data::synth_graphs::{self, GraphSynthConfig};
use spp::data::synth_itemsets::{generate, ItemsetSynthConfig};
use spp::mining::{PatternNode, PatternSubstrate, Walk};
use spp::screening::lambda_max::lambda_max;
use spp::screening::sppc::SppScreen;
use spp::screening::SupportPool;
use spp::solver::dual::safe_radius;
use spp::solver::problem::{dual_value, primal_value};
use spp::solver::{CdSolver, Task};
use spp::testutil::oracle;

/// Solve the FULL problem over every enumerated pattern; return
/// (per-pattern |α_tᵀθ*|, primal*).
fn full_space_solve(
    db: &spp::data::Transactions,
    y: &[f64],
    task: Task,
    maxpat: usize,
    lam: f64,
) -> (Vec<f64>, f64) {
    let all = oracle::all_itemsets(db, maxpat);
    let supports: Vec<Vec<u32>> = all.iter().map(|(_, s)| s.clone()).collect();
    let mut solver = CdSolver::default();
    solver.cfg.tol = 1e-10;
    let sol = solver.solve(task, &supports, y, lam, None);
    assert!(sol.gap <= 1e-9, "oracle solve did not converge: {}", sol.gap);
    let g: Vec<f64> = y
        .iter()
        .zip(&sol.theta)
        .map(|(&yi, &ti)| task.a(yi) * ti)
        .collect();
    let corr: Vec<f64> = supports
        .iter()
        .map(|s| s.iter().map(|&i| g[i as usize]).sum::<f64>().abs())
        .collect();
    (corr, sol.primal)
}

fn safety_case(seed: u64, task: Task) {
    let d = generate(&ItemsetSynthConfig::tiny(seed, task == Task::Classification));
    let db = &d.db;
    let maxpat = 3;
    let lm = lambda_max(db, &d.y, task, maxpat, 1);

    for frac in [0.7, 0.3, 0.1] {
        let lam = frac * lm.lambda_max;
        let (corr, full_primal) = full_space_solve(&d.db, &d.y, task, maxpat, lam);

        // screening pair: the zero solution at λ_max (a deliberately
        // weak-but-feasible pair — safety must hold regardless)
        let theta0: Vec<f64> = lm.slack0.iter().map(|&s| s / lm.lambda_max).collect();
        let primal = primal_value(&lm.slack0, 0.0, lam);
        let dualv = dual_value(task, &theta0, &d.y, lam);
        let radius = safe_radius(primal, dualv, lam);

        let mut pool = SupportPool::new();
        let mut screen = SppScreen::new(task, &d.y, &theta0, radius, &mut pool);
        db.traverse(maxpat, 1, &mut screen);
        let survivors = std::mem::take(&mut screen.survivors);
        drop(screen);

        let survivor_items: std::collections::HashSet<Vec<u32>> = survivors
            .iter()
            .map(|s| match &s.pattern {
                spp::mining::Pattern::Itemset(v) => v.clone(),
                _ => unreachable!(),
            })
            .collect();
        let all = oracle::all_itemsets(&d.db, maxpat);
        let mut pruned_count = 0;
        for ((items, _), &c) in all.iter().zip(&corr) {
            if !survivor_items.contains(items) {
                pruned_count += 1;
                assert!(
                    c < 1.0 + 1e-6,
                    "UNSAFE: pruned pattern {items:?} has |corr| = {c} \
                     at λ = {frac}·λmax (seed {seed})"
                );
            }
        }
        // Lemma 1: solving only Â reproduces the full optimum
        let supports: Vec<&[u32]> = survivors.iter().map(|s| pool.get(s.support)).collect();
        let mut solver = CdSolver::default();
        solver.cfg.tol = 1e-10;
        let restricted = solver.solve(task, &supports, &d.y, lam, None);
        assert!(
            (restricted.primal - full_primal).abs() < 1e-6 * (1.0 + full_primal.abs()),
            "Lemma 1 violated: restricted {} vs full {} (λ={frac}·λmax seed={seed})",
            restricted.primal,
            full_primal
        );
        if frac >= 0.7 {
            assert!(pruned_count > 0, "no pruning at λ={frac}·λmax (seed {seed})");
        }
    }
}

#[test]
fn spp_is_safe_regression() {
    for seed in [101, 102, 103, 104] {
        safety_case(seed, Task::Regression);
    }
}

#[test]
fn spp_is_safe_classification() {
    for seed in [201, 202, 203, 204] {
        safety_case(seed, Task::Classification);
    }
}

/// gSpan enumerates exactly the canonical subgraph classes with exactly
/// the right supports (validated against the permutation-canonical
/// brute force), so the itemset safety argument transfers to graphs.
#[test]
fn gspan_matches_bruteforce_enumeration() {
    for seed in [11u64, 12, 13] {
        let mut cfg = GraphSynthConfig::tiny(seed, true);
        cfg.n = 12;
        cfg.min_atoms = 3;
        cfg.max_atoms = 6;
        let d = synth_graphs::generate(&cfg);
        let maxpat = 3;

        let mut mined: Vec<(String, Vec<u32>)> = Vec::new();
        let mut v = |n: &PatternNode<'_>| {
            if let spp::mining::Pattern::Subgraph(code) = n.to_pattern() {
                let g = spp::mining::gspan::code_to_labeled_graph(&code);
                mined.push((oracle::canonical_form(&g), n.support.to_vec()));
            }
            Walk::Descend
        };
        d.db.traverse(maxpat, 1, &mut v);

        let brute = oracle::all_subgraphs_canonical(&d.db, maxpat);
        let mut seen = std::collections::HashSet::new();
        for (c, _) in &mined {
            assert!(seen.insert(c.clone()), "duplicate canonical pattern {c}");
        }
        assert_eq!(
            mined.len(),
            brute.len(),
            "gSpan found {} classes, brute force {} (seed {seed})",
            mined.len(),
            brute.len()
        );
        for (c, sup) in &mined {
            let bs = brute
                .get(c)
                .unwrap_or_else(|| panic!("gSpan pattern {c} not in brute force"));
            assert_eq!(sup, bs, "support mismatch for {c}");
        }
    }
}

/// The SPP rule on the gSpan tree: pruned patterns are inactive at the
/// optimum of the full problem built by brute-force enumeration.
#[test]
fn spp_is_safe_on_graphs() {
    let mut cfg = GraphSynthConfig::tiny(31, false);
    cfg.n = 14;
    cfg.min_atoms = 3;
    cfg.max_atoms = 6;
    let d = synth_graphs::generate(&cfg);
    let db = &d.db;
    let maxpat = 3;
    let task = Task::Regression;
    let lm = lambda_max(db, &d.db.y, task, maxpat, 1);
    let lam = 0.4 * lm.lambda_max;

    let brute = oracle::all_subgraphs_canonical(&d.db, maxpat);
    let supports: Vec<Vec<u32>> = brute.values().cloned().collect();
    let canon_keys: Vec<&String> = brute.keys().collect();
    let mut solver = CdSolver::default();
    solver.cfg.tol = 1e-10;
    let sol = solver.solve(task, &supports, &d.db.y, lam, None);
    let corr: Vec<f64> = supports
        .iter()
        .map(|s| s.iter().map(|&i| sol.theta[i as usize]).sum::<f64>().abs())
        .collect();

    let theta0: Vec<f64> = lm.slack0.iter().map(|&s| s / lm.lambda_max).collect();
    let primal = primal_value(&lm.slack0, 0.0, lam);
    let dualv = dual_value(task, &theta0, &d.db.y, lam);
    let radius = safe_radius(primal, dualv, lam);
    let mut pool = SupportPool::new();
    let mut screen = SppScreen::new(task, &d.db.y, &theta0, radius, &mut pool);
    db.traverse(maxpat, 1, &mut screen);

    let surviving: std::collections::HashSet<String> = screen
        .survivors
        .iter()
        .map(|s| match &s.pattern {
            spp::mining::Pattern::Subgraph(code) => {
                oracle::canonical_form(&spp::mining::gspan::code_to_labeled_graph(code))
            }
            _ => unreachable!(),
        })
        .collect();
    for (key, &c) in canon_keys.iter().zip(&corr) {
        if !surviving.contains(*key) {
            assert!(c < 1.0 + 1e-6, "UNSAFE graph pruning: {key} has |corr| {c}");
        }
    }
}
