//! PJRT runtime integration: the AOT JAX/Pallas artifacts, executed
//! from Rust, must agree with the pure-Rust engines bit-for-bit up to
//! f32 rounding — including padding behaviour.
//!
//! These tests need `artifacts/` (run `make artifacts` first); they are
//! skipped gracefully when the manifest is absent so `cargo test` works
//! in a fresh checkout.

use spp::data::synth_itemsets::{generate, ItemsetSynthConfig};
use spp::path::{compute_path_spp, compute_path_spp_with, PathConfig};
use spp::runtime::{
    default_artifact_dir, engine::XlaRestricted, PjrtRuntime, XlaFistaSolver, XlaSppcScorer,
};
use spp::screening::fold_weights;
use spp::solver::{CdSolver, Task};
use spp::testutil::SplitMix64;

fn runtime() -> Option<PjrtRuntime> {
    let dir = default_artifact_dir();
    if !dir.join("manifest.txt").is_file() {
        eprintln!("skipping: no artifacts at {}", dir.display());
        return None;
    }
    // Also skip when the runtime itself is unavailable (e.g. a default
    // build without the `pjrt` feature): artifacts existing on disk
    // must not turn these tests into failures.
    match PjrtRuntime::cpu(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping: {e}");
            None
        }
    }
}

fn random_supports(rng: &mut SplitMix64, n: usize, k: usize, max_len: usize) -> Vec<Vec<u32>> {
    (0..k)
        .map(|_| {
            let m = rng.range(1, max_len.min(n - 1).max(2));
            rng.sample_distinct(n, m).into_iter().map(|i| i as u32).collect()
        })
        .collect()
}

#[test]
fn sppc_scorer_matches_rust_fold() {
    let Some(rt) = runtime() else { return };
    let mut rng = SplitMix64::new(7);
    // n deliberately NOT a padded size: exercises zero-padding
    for n in [100usize, 777, 1024] {
        let y: Vec<f64> = (0..n).map(|_| if rng.coin(0.5) { 1.0 } else { -1.0 }).collect();
        let theta: Vec<f64> = (0..n).map(|_| rng.gauss() * 0.2).collect();
        for task in [Task::Regression, Task::Classification] {
            let (wpos, wneg) = fold_weights(task, &y, &theta);
            let supports = random_supports(&mut rng, n, 600, 40);
            let scorer = XlaSppcScorer::new(&rt, n).unwrap();
            let scores = scorer.score(&supports, &wpos, &wneg, 0.45).unwrap();
            assert_eq!(scores.len(), supports.len());
            for (sup, sc) in supports.iter().zip(&scores) {
                let pos: f64 = sup.iter().map(|&i| wpos[i as usize]).sum();
                let neg: f64 = sup.iter().map(|&i| wneg[i as usize]).sum();
                let v = sup.len() as f64;
                let want_u = pos.max(-neg);
                let want = want_u + 0.45 * v.sqrt();
                assert!((sc.u - want_u).abs() < 1e-3, "u {} vs {}", sc.u, want_u);
                assert!((sc.v - v).abs() < 1e-3, "v {} vs {}", sc.v, v);
                assert!((sc.sppc - want).abs() < 1e-3, "sppc {} vs {}", sc.sppc, want);
            }
        }
    }
}

#[test]
fn sppc_scorer_multi_block_frontiers() {
    let Some(rt) = runtime() else { return };
    let mut rng = SplitMix64::new(8);
    let n = 300;
    let y: Vec<f64> = (0..n).map(|_| 1.0).collect();
    let theta: Vec<f64> = (0..n).map(|_| rng.gauss() * 0.1).collect();
    let (wpos, wneg) = fold_weights(Task::Regression, &y, &theta);
    let scorer = XlaSppcScorer::new(&rt, n).unwrap();
    // more supports than one block to force chunking
    let k = scorer.block_width() * 2 + 17;
    let supports = random_supports(&mut rng, n, k, 30);
    let scores = scorer.score(&supports, &wpos, &wneg, 0.0).unwrap();
    assert_eq!(scores.len(), k);
    // zero radius: sppc == u
    for sc in &scores {
        assert!((sc.sppc - sc.u).abs() < 1e-4);
    }
}

#[test]
fn fista_solver_matches_cd_on_both_tasks() {
    let Some(rt) = runtime() else { return };
    let mut rng = SplitMix64::new(9);
    let n = 500;
    let supports = random_supports(&mut rng, n, 60, 80);
    for task in [Task::Regression, Task::Classification] {
        let y: Vec<f64> = match task {
            Task::Regression => (0..n).map(|_| rng.gauss() * 2.0).collect(),
            Task::Classification => {
                (0..n).map(|_| if rng.coin(0.5) { 1.0 } else { -1.0 }).collect()
            }
        };
        let lam = 1.5;
        let xs = XlaFistaSolver::new(&rt).solve(task, &supports, &y, lam).unwrap();
        let cd = CdSolver::default().solve(task, &supports, &y, lam, None);
        let rel = (xs.primal - cd.primal).abs() / cd.primal.abs().max(1.0);
        assert!(rel < 5e-3, "{task:?}: fista {} vs cd {}", xs.primal, cd.primal);
        assert!(xs.gap >= -1e-3, "negative gap {}", xs.gap);
    }
}

#[test]
fn xla_engine_path_equals_cd_engine_path() {
    let Some(rt) = runtime() else { return };
    let d = generate(&ItemsetSynthConfig::tiny(55, false));
    let db = &d.db;
    let cfg = PathConfig {
        n_lambdas: 6,
        lambda_min_ratio: 0.1,
        maxpat: 2,
        ..PathConfig::default()
    };
    let rust_path = compute_path_spp(db, &d.y, Task::Regression, &cfg).unwrap();
    let solver = XlaRestricted::new(&rt);
    let xla_path = compute_path_spp_with(db, &d.y, Task::Regression, &cfg, &solver).unwrap();
    assert_eq!(rust_path.points.len(), xla_path.points.len());
    for (a, b) in rust_path.points.iter().zip(&xla_path.points) {
        let l1a: f64 = a.active.iter().map(|(_, w)| w.abs()).sum();
        let l1b: f64 = b.active.iter().map(|(_, w)| w.abs()).sum();
        assert!(
            (l1a - l1b).abs() < 1e-3 * (1.0 + l1a.abs()),
            "λ={}: ‖w‖₁ {} vs {}",
            a.lambda,
            l1a,
            l1b
        );
        assert!(b.gap <= 2e-6, "xla path point not certified: gap {}", b.gap);
    }
}

#[test]
fn oversized_problems_fall_back_to_cd() {
    let Some(rt) = runtime() else { return };
    let solver = XlaRestricted::new(&rt);
    // n bigger than any artifact -> must fall back, still correct
    let mut rng = SplitMix64::new(10);
    let n = 40_000;
    let supports = random_supports(&mut rng, n, 5, 50);
    let y: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
    use spp::columns::ColumnView;
    use spp::path::RestrictedSolver;
    let views: Vec<ColumnView> =
        supports.iter().map(|s| ColumnView::Sparse(s.as_slice())).collect();
    let sol = solver.solve_restricted(Task::Regression, &views, &y, 5.0, &[0.0; 5], 0.0);
    assert!(sol.gap <= 1e-6);
    assert!(solver.fallbacks.get() >= 1);
}
