//! Hybrid sparse/bitset support columns, end to end — two layers:
//!
//! 1. **Property round-trips**: [`HybridColumn`] against the
//!    sorted-`Vec<u32>` oracle at every boundary size (0, 1, 63, 64,
//!    65, the dense cutoff ±1, chunk-span ±1, one id per chunk, every
//!    record) — intern → iterate → intersect → dot, with the float
//!    kernels compared **bitwise** (the word kernels must reproduce the
//!    scalar accumulation order exactly, not merely approximately).
//! 2. **Differential kernel-oracle**: full SPP paths with a sparse pool
//!    vs a hybrid pool must be bit-identical — active sets,
//!    weight/intercept/gap bits, |Â|, solver epochs, node counts, reuse
//!    telemetry — on all three shipped substrates, crossed with
//!    forest/scratch screening and per-λ/chunked grids.  The layouts
//!    are requested through `PathConfig::columns` (never the
//!    environment, which tests must not race on).

use spp::columns::{ColumnLayout, ColumnRead, HybridColumn, CHUNK_SPAN, DENSE_CUTOFF};
use spp::data::sequence::{self, SeqSynthConfig};
use spp::data::synth_graphs::{self, GraphSynthConfig};
use spp::data::synth_itemsets::{self, ItemsetSynthConfig};
use spp::mining::PatternSubstrate;
use spp::path::{compute_path_spp, PathConfig, PathResult};
use spp::solver::Task;
use spp::testutil::SplitMix64;

// ---------------------------------------------------------------------------
// layer 1: property round-trips vs the sorted-Vec<u32> oracle
// ---------------------------------------------------------------------------

fn scalar_dot(ids: &[u32], g: &[f64]) -> f64 {
    let mut acc = 0.0;
    for &i in ids {
        acc += g[i as usize];
    }
    acc
}

fn scalar_fold(ids: &[u32], g: &[f64]) -> (f64, f64) {
    let (mut pos, mut neg) = (0.0f64, 0.0f64);
    for &i in ids {
        let gi = g[i as usize];
        pos += gi.max(0.0);
        neg += gi.min(0.0);
    }
    (pos, neg)
}

fn scalar_intersect(a: &[u32], b: &[u32]) -> Vec<u32> {
    a.iter().filter(|x| b.binary_search(x).is_ok()).copied().collect()
}

/// Every boundary size the chunk/word geometry exposes, plus the two
/// degenerate shapes: one id per chunk and all records present.
fn boundary_columns(rng: &mut SplitMix64, n: usize) -> Vec<Vec<u32>> {
    let span = CHUNK_SPAN as usize;
    let sizes = [
        0,
        1,
        63,
        64,
        65,
        DENSE_CUTOFF - 1,
        DENSE_CUTOFF,
        DENSE_CUTOFF + 1,
        span - 1,
        span,
        span + 1,
        n / 2,
        n - 1,
        n,
    ];
    let mut cols: Vec<Vec<u32>> = sizes
        .iter()
        .map(|&m| rng.sample_distinct(n, m).into_iter().map(|i| i as u32).collect())
        .collect();
    cols.push((0..n as u32).step_by(span).collect()); // one id per chunk
    cols.push((0..n as u32).collect()); // every record, again, contiguous
    cols
}

#[test]
fn boundary_columns_round_trip_and_dot_bitwise() {
    let mut rng = SplitMix64::new(61);
    let n = 3 * CHUNK_SPAN as usize + 137;
    let g: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
    for ids in boundary_columns(&mut rng, n) {
        let col = HybridColumn::from_sorted(ids.clone());
        // intern → iterate: the canonical sorted ids survive
        assert_eq!(col.ids(), &ids[..]);
        assert_eq!(col.len(), ids.len());
        let mut walked = Vec::with_capacity(ids.len());
        col.for_each_id(|i| walked.push(i as u32));
        assert_eq!(walked, ids, "for_each_id must yield ascending ids");
        // dot / fold: bitwise against the scalar oracle
        assert_eq!(col.dot_words(&g).to_bits(), scalar_dot(&ids, &g).to_bits());
        let (hp, hn) = col.fold_signed_words(&g);
        let (sp, sn) = scalar_fold(&ids, &g);
        assert_eq!((hp.to_bits(), hn.to_bits()), (sp.to_bits(), sn.to_bits()));
        // membership probes agree with binary search on the boundary
        for probe in [0u32, 63, 64, CHUNK_SPAN - 1, CHUNK_SPAN, n as u32 - 1] {
            assert_eq!(col.contains(probe), ids.binary_search(&probe).is_ok(), "probe {probe}");
        }
    }
}

#[test]
fn boundary_columns_intersect_like_the_oracle() {
    let mut rng = SplitMix64::new(67);
    let n = 2 * CHUNK_SPAN as usize + 513;
    let cols = boundary_columns(&mut rng, n);
    let hybrids: Vec<HybridColumn> =
        cols.iter().map(|c| HybridColumn::from_sorted(c.clone())).collect();
    let mut out = HybridColumn::default();
    for (a, ha) in cols.iter().zip(&hybrids) {
        for (b, hb) in cols.iter().zip(&hybrids) {
            HybridColumn::intersect_into(ha, hb, &mut out);
            let want = scalar_intersect(a, b);
            assert_eq!(out.ids(), &want[..], "|a|={} |b|={}", a.len(), b.len());
            // the result is itself a well-formed column: re-intersecting
            // with a full set round-trips it
            let full = HybridColumn::from_sorted((0..n as u32).collect());
            let mut again = HybridColumn::default();
            HybridColumn::intersect_into(&out, &full, &mut again);
            assert_eq!(again.ids(), &want[..]);
        }
    }
}

// ---------------------------------------------------------------------------
// layer 2: differential kernel-oracle — sparse vs hybrid full paths
// ---------------------------------------------------------------------------

fn cfg(n_lambdas: usize, maxpat: usize, reuse: bool, chunk: usize) -> PathConfig {
    PathConfig {
        n_lambdas,
        lambda_min_ratio: 0.05,
        maxpat,
        reuse_forest: reuse,
        range_chunk: chunk,
        ..PathConfig::default()
    }
}

/// Bitwise equality of everything the two layouts produced, telemetry
/// included: the hybrid kernels must not change what work happens, only
/// how each fold/intersection is computed.
fn assert_results_bitwise(a: &PathResult, b: &PathResult) {
    assert_eq!(a.lambda_max.to_bits(), b.lambda_max.to_bits());
    assert_eq!(a.points.len(), b.points.len());
    for (p, q) in a.points.iter().zip(&b.points) {
        assert_eq!(p.lambda.to_bits(), q.lambda.to_bits());
        assert_eq!(p.active.len(), q.active.len(), "active-set size at λ={}", p.lambda);
        for ((pa, wa), (pb, wb)) in p.active.iter().zip(&q.active) {
            assert_eq!(pa, pb, "active pattern/order mismatch at λ={}", p.lambda);
            assert_eq!(
                wa.to_bits(),
                wb.to_bits(),
                "weight bits differ at λ={} on {}: {wa} vs {wb}",
                p.lambda,
                pa.display()
            );
        }
        assert_eq!(p.b.to_bits(), q.b.to_bits(), "intercept bits at λ={}", p.lambda);
        assert_eq!(p.gap.to_bits(), q.gap.to_bits(), "gap bits at λ={}", p.lambda);
        assert!(p.gap <= 2e-6, "uncertified λ={}", p.lambda);
        assert_eq!(p.working_size, q.working_size, "|Â| at λ={}", p.lambda);
        assert_eq!(p.cd_epochs, q.cd_epochs, "solver epochs at λ={}", p.lambda);
        assert_eq!(p.stats, q.stats, "node counts at λ={}", p.lambda);
        assert_eq!(p.reuse, q.reuse, "reuse telemetry at λ={}", p.lambda);
    }
}

/// Sparse vs hybrid on one substrate/config (layouts via the config,
/// never the environment).
fn case<S: PatternSubstrate>(db: &S, y: &[f64], task: Task, base: &PathConfig) {
    let mut sparse = *base;
    sparse.columns = Some(ColumnLayout::Sparse);
    let mut hybrid = *base;
    hybrid.columns = Some(ColumnLayout::Hybrid);
    let a = compute_path_spp(db, y, task, &sparse).unwrap();
    let b = compute_path_spp(db, y, task, &hybrid).unwrap();
    assert_results_bitwise(&a, &b);
}

#[test]
fn itemsets_sparse_vs_hybrid_bit_identical() {
    for (seed, classify) in [(111u64, false), (112, true)] {
        let d = synth_itemsets::generate(&ItemsetSynthConfig::tiny(seed, classify));
        let task = if classify {
            Task::Classification
        } else {
            Task::Regression
        };
        for reuse in [true, false] {
            for chunk in [1usize, 4] {
                case(&d.db, &d.y, task, &cfg(10, 3, reuse, chunk));
            }
        }
    }
}

#[test]
fn graphs_sparse_vs_hybrid_bit_identical() {
    for (seed, classify) in [(113u64, false), (114, true)] {
        let d = synth_graphs::generate(&GraphSynthConfig::tiny(seed, classify));
        let task = if classify {
            Task::Classification
        } else {
            Task::Regression
        };
        for reuse in [true, false] {
            for chunk in [1usize, 4] {
                case(&d.db, &d.db.y, task, &cfg(8, 3, reuse, chunk));
            }
        }
    }
}

#[test]
fn sequences_sparse_vs_hybrid_bit_identical() {
    for (seed, classify) in [(115u64, false), (116, true)] {
        let d = sequence::generate(&SeqSynthConfig::tiny(seed, classify));
        let task = if classify {
            Task::Classification
        } else {
            Task::Regression
        };
        for reuse in [true, false] {
            for chunk in [1usize, 4] {
                case(&d.db, &d.y, task, &cfg(8, 3, reuse, chunk));
            }
        }
    }
}

#[test]
fn hybrid_layout_is_bit_identical_across_worker_counts() {
    // the parallel contract holds under the hybrid kernels too: threads
    // 1 vs N with hybrid columns, full bitwise equality incl. telemetry
    let d = synth_itemsets::generate(&ItemsetSynthConfig::tiny(117, false));
    let mut c1 = cfg(10, 3, true, 4);
    c1.columns = Some(ColumnLayout::Hybrid);
    c1.threads = 1;
    let mut c4 = c1;
    c4.threads = 4;
    let a = compute_path_spp(&d.db, &d.y, Task::Regression, &c1).unwrap();
    let b = compute_path_spp(&d.db, &d.y, Task::Regression, &c4).unwrap();
    assert_results_bitwise(&a, &b);
}

#[test]
fn dense_preset_runs_the_word_kernels_and_stays_identical() {
    // splice is the dense regime (supports cover most records): the
    // hybrid pool actually builds bitmap chunks here, so this pins the
    // word kernels — not just the sparse fallback — against the oracle
    let data = spp::data::registry::lookup("splice", 0.08).unwrap();
    let spp::data::registry::Dataset::Itemsets(t) = &data else {
        unreachable!()
    };
    case(&t.db, &t.y, Task::Classification, &cfg(8, 3, true, 1));
}
