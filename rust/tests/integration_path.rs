//! Path-level integration: both methods, both tasks, both database
//! kinds, agreeing on every optimum along the full regularization path.

use spp::data::synth_graphs::{self, GraphSynthConfig};
use spp::data::synth_itemsets::{generate, ItemsetSynthConfig};
use spp::path::{compute_path_boosting, compute_path_spp, PathConfig};
use spp::solver::Task;

fn cfg(n_lambdas: usize, maxpat: usize) -> PathConfig {
    PathConfig {
        n_lambdas,
        lambda_min_ratio: 0.05,
        maxpat,
        ..PathConfig::default()
    }
}

/// Both methods must agree on (‖w‖₁, b, certified gap) at every λ.
fn assert_paths_agree(spp: &spp::path::PathResult, boost: &spp::path::PathResult) {
    assert_eq!(spp.points.len(), boost.points.len());
    assert!((spp.lambda_max - boost.lambda_max).abs() < 1e-9);
    for (a, b) in spp.points.iter().zip(&boost.points) {
        let l1a: f64 = a.active.iter().map(|(_, w)| w.abs()).sum();
        let l1b: f64 = b.active.iter().map(|(_, w)| w.abs()).sum();
        assert!(
            (l1a - l1b).abs() < 1e-3 * (1.0 + l1a.abs()),
            "‖w‖₁ mismatch at λ={}: {} vs {}",
            a.lambda,
            l1a,
            l1b
        );
        assert!((a.b - b.b).abs() < 2e-3, "b mismatch at λ={}", a.lambda);
        assert!(a.gap <= 2e-6 && b.gap <= 2e-6);
    }
}

#[test]
fn itemset_regression_path_agreement() {
    let d = generate(&ItemsetSynthConfig::tiny(41, false));
    let db = &d.db;
    let c = cfg(8, 3);
    assert_paths_agree(
        &compute_path_spp(db, &d.y, Task::Regression, &c).unwrap(),
        &compute_path_boosting(db, &d.y, Task::Regression, &c).unwrap(),
    );
}

#[test]
fn itemset_classification_path_agreement() {
    let d = generate(&ItemsetSynthConfig::tiny(42, true));
    let db = &d.db;
    let c = cfg(8, 3);
    assert_paths_agree(
        &compute_path_spp(db, &d.y, Task::Classification, &c).unwrap(),
        &compute_path_boosting(db, &d.y, Task::Classification, &c).unwrap(),
    );
}

#[test]
fn graph_regression_path_agreement() {
    let d = synth_graphs::generate(&GraphSynthConfig::tiny(43, false));
    let db = &d.db;
    let c = cfg(6, 3);
    assert_paths_agree(
        &compute_path_spp(db, &d.db.y, Task::Regression, &c).unwrap(),
        &compute_path_boosting(db, &d.db.y, Task::Regression, &c).unwrap(),
    );
}

#[test]
fn graph_classification_path_agreement() {
    let d = synth_graphs::generate(&GraphSynthConfig::tiny(44, true));
    let db = &d.db;
    let c = cfg(6, 3);
    assert_paths_agree(
        &compute_path_spp(db, &d.db.y, Task::Classification, &c).unwrap(),
        &compute_path_boosting(db, &d.db.y, Task::Classification, &c).unwrap(),
    );
}

#[test]
fn spp_node_counts_beat_boosting_and_grow_with_maxpat() {
    // The paper's Figure 4/5 regime needs *many active patterns* at
    // small λ (each one costs boosting a full search round).  Per-point
    // strict dominance is NOT a theorem — on toy trees with few active
    // patterns boosting's incumbent-driven envelope can out-prune the
    // SPP rule — so this uses the splice preset (dense, paper-shaped)
    // and asserts the aggregate.  Node counts here are the *paper's*
    // from-scratch currency, so the incremental forest is off (its
    // accounting is pinned separately in integration_forest.rs).
    let c = ItemsetSynthConfig::preset_splice(45).scaled(0.1);
    let d = generate(&c);
    let db = &d.db;
    let mut prev_nodes = 0u64;
    let (mut spp_total, mut boost_total) = (0u64, 0u64);
    for maxpat in [2usize, 3] {
        let mut c = cfg(8, maxpat);
        c.reuse_forest = false;
        // paper-currency node counts: per-λ screening pinned too
        c.range_chunk = 1;
        let spp = compute_path_spp(db, &d.y, Task::Regression, &c).unwrap();
        let boost = compute_path_boosting(db, &d.y, Task::Regression, &c).unwrap();
        spp_total += spp.total_nodes();
        boost_total += boost.total_nodes();
        assert!(spp.total_nodes() >= prev_nodes, "node count shrank with maxpat");
        prev_nodes = spp.total_nodes();
    }
    assert!(
        spp_total < boost_total,
        "aggregate: spp {spp_total} >= boosting {boost_total}"
    );
}

#[test]
fn warm_screening_prunes_more_than_cold() {
    // the radius shrinks as λ decreases slowly with warm pairs; verify
    // per-λ survivor counts stay well below the full pattern count
    let d = generate(&ItemsetSynthConfig::tiny(46, false));
    let db = &d.db;
    let c = cfg(10, 3);
    let path = compute_path_spp(db, &d.y, Task::Regression, &c).unwrap();
    let total_patterns = spp::testutil::oracle::all_itemsets(&d.db, 3).len();
    // at the largest few λ the working set must be a small fraction
    for p in &path.points[1..4] {
        assert!(
            p.working_size * 2 < total_patterns,
            "screening kept {}/{} at λ={}",
            p.working_size,
            total_patterns,
            p.lambda
        );
    }
}

#[test]
fn boosting_rounds_exceed_one_at_small_lambda() {
    let d = generate(&ItemsetSynthConfig::tiny(47, false));
    let db = &d.db;
    let path = compute_path_boosting(db, &d.y, Task::Regression, &cfg(8, 3)).unwrap();
    let max_rounds = path.points.iter().map(|p| p.rounds).max().unwrap();
    assert!(max_rounds > 1, "boosting never generated constraints");
    // SPP always does exactly one search per λ
    let spp = compute_path_spp(db, &d.y, Task::Regression, &cfg(8, 3)).unwrap();
    assert!(spp.points.iter().all(|p| p.rounds == 1));
}
