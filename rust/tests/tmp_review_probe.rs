//! Review probe: graph prefix-gate soundness for relabeling DFS codes.

use spp::model::SparsePatternModel;
use spp::serve::compiled::CompiledModel;
use spp::data::graph::Graph;

#[test]
fn relabeling_code_gate_vs_naive() {
    // Edge 1 relabels vertex 1 from 6 to 7. parse_pattern accepts this
    // (all labels determined, connected), the miner would never emit it.
    let text = "spp-model v1 task=regression lambda=1 b=0\nG 1 0:1:5:0:6,1:2:7:0:8\n";
    let model = SparsePatternModel::parse(text).expect("model should parse");
    let compiled = CompiledModel::compile_for(&model, "G").expect("compile");
    // Record = the pattern graph itself per code_to_labeled_graph:
    // labels [5,7,8], path edges.
    let mut g = Graph::new();
    g.add_vertex(5);
    g.add_vertex(7);
    g.add_vertex(8);
    g.add_edge(0, 1, 0);
    g.add_edge(1, 2, 0);
    let naive = model.score_graph(&g);
    let out = compiled.score_graphs(&[g], 1).expect("score");
    assert_eq!(
        out.scores[0].to_bits(),
        naive.to_bits(),
        "compiled={} naive={}",
        out.scores[0],
        naive
    );
}
