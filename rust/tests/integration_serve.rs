//! The serve subsystem, end to end: compiled-matcher bit-identity
//! against the naive scorer on all three substrates, protocol
//! round-trips over in-memory sessions, error paths that must not end
//! a session, hot reload, thread-count byte-identity, and a golden
//! replay of the exact canned session CI pipes through the binary.

use spp::data::registry::{self, Dataset};
use spp::mining::{Pattern, PatternNode, PatternSubstrate, Walk};
use spp::model::SparsePatternModel;
use spp::serve::compiled::CompiledModel;
use spp::serve::{run_session, ServeEngine};
use spp::solver::Task;

/// Mine every pattern of a registry dataset (bounded) and attach
/// deterministic nonzero weights — a "fitted" model with full
/// coverage of the substrate's pattern shapes, without a solver run.
fn mined_model(data: &Dataset, task: Task, maxpat: usize, minsup: usize) -> SparsePatternModel {
    let mut pats: Vec<Pattern> = Vec::new();
    {
        let mut v = |n: &PatternNode<'_>| {
            pats.push(n.to_pattern());
            Walk::Descend
        };
        match data {
            Dataset::Graphs(g) => g.traverse(maxpat, minsup, &mut v),
            Dataset::Itemsets(t) => t.db.traverse(maxpat, minsup, &mut v),
            Dataset::Sequences(s) => s.db.traverse(maxpat, minsup, &mut v),
        }
    }
    assert!(!pats.is_empty(), "mining produced no patterns");
    pats.truncate(300);
    let terms = pats
        .into_iter()
        .enumerate()
        .map(|(i, p)| (p, ((i % 7) as f64 - 3.0) * 0.25 + 0.125))
        .collect();
    SparsePatternModel { task, lambda: 0.25, b: 0.375, terms }
}

/// Naive per-record scores through the substrate matcher (the oracle).
fn naive_scores(model: &SparsePatternModel, data: &Dataset) -> Vec<f64> {
    match data {
        Dataset::Graphs(g) => g.graphs.iter().map(|r| model.score_graph(r)).collect(),
        Dataset::Itemsets(t) => t.db.items.iter().map(|r| model.score_itemset(r)).collect(),
        Dataset::Sequences(s) => s.db.seqs.iter().map(|r| model.score_sequence(r)).collect(),
    }
}

fn assert_compiled_bit_identical(dataset: &str, scale: f64, maxpat: usize, minsup: usize) {
    let info = registry::info(dataset).unwrap();
    let data = registry::lookup(dataset, scale).unwrap();
    let model = mined_model(&data, info.task, maxpat, minsup);
    let kind = model.terms[0].0.kind_tag();
    let compiled = CompiledModel::compile_for(&model, kind).unwrap();
    assert_eq!(compiled.stats.compiled_terms, model.terms.len());
    let oracle = naive_scores(&model, &data);
    let mut per_thread_ops = Vec::new();
    for threads in [1usize, 4] {
        let out = compiled.score_dataset(&data, threads).unwrap();
        assert_eq!(out.scores.len(), oracle.len());
        for (i, (&a, &b)) in out.scores.iter().zip(&oracle).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{dataset}: compiled score differs from naive at record {i}: {a} vs {b}"
            );
        }
        per_thread_ops.push(out.ops);
    }
    assert_eq!(per_thread_ops[0], per_thread_ops[1], "{dataset}: ops depend on thread count");
}

#[test]
fn compiled_matcher_bit_identical_itemsets() {
    assert_compiled_bit_identical("splice", 0.2, 3, 5);
}

#[test]
fn compiled_matcher_bit_identical_graphs() {
    assert_compiled_bit_identical("cpdb", 0.1, 3, 2);
}

#[test]
fn compiled_matcher_bit_identical_sequences() {
    assert_compiled_bit_identical("synth-seq", 0.2, 3, 2);
}

/// Run a whole session through the in-memory transport and return the
/// response lines.
fn session(threads: usize, input: &str) -> Vec<String> {
    let mut engine = ServeEngine::new(threads);
    let mut out = Vec::new();
    run_session(&mut engine, input.as_bytes(), &mut out).unwrap();
    String::from_utf8(out).unwrap().lines().map(str::to_string).collect()
}

const SMOKE_MODEL_LINE: &str =
    r#"{"op":"load","id":1,"model":"spp-model v1 task=classification lambda=1 b=0\nI 2 1,2\nI -1 3\n"}"#;

#[test]
fn protocol_round_trip_load_score_stats_unload() {
    let input = format!(
        "{SMOKE_MODEL_LINE}\n{}\n{}\n{}\n{}\n",
        r#"{"op":"score","id":2,"kind":"I","records":[[1,2],[3],[2,1,1]]}"#,
        r#"{"op":"stats","id":3}"#,
        r#"{"op":"unload","id":4,"kind":"I"}"#,
        r#"{"op":"list","id":5}"#,
    );
    let lines = session(1, &input);
    assert_eq!(lines.len(), 5);
    assert!(
        lines[0].contains(r#""kind":"I","task":"classification""#)
            && lines[0].contains(r#""patterns":2"#),
        "load reply: {}",
        lines[0]
    );
    // [2,1,1] normalizes to {1,2} and scores like it.
    assert!(
        lines[1].contains(r#""scores":[2,-1,2],"preds":[1,-1,1]"#),
        "score reply: {}",
        lines[1]
    );
    assert!(
        lines[2].contains(r#""requests":3,"errors":0,"loads":1"#)
            && lines[2].contains(r#""records_scored":3"#),
        "stats reply: {}",
        lines[2]
    );
    assert!(lines[3].contains(r#""unloaded":true"#), "unload reply: {}", lines[3]);
    assert!(lines[4].ends_with(r#""result":{"models":[]}}"#), "list reply: {}", lines[4]);
}

#[test]
fn hot_reload_swaps_the_model_mid_stream() {
    let reload =
        r#"{"op":"load","id":2,"model":"spp-model v1 task=classification lambda=1 b=0\nI 5 1\n"}"#;
    let score = r#"{"op":"score","kind":"I","records":[[1]]}"#;
    let input = format!("{SMOKE_MODEL_LINE}\n{score}\n{reload}\n{score}\n");
    let lines = session(1, &input);
    assert_eq!(lines.len(), 4);
    assert!(lines[1].contains(r#""scores":[0]"#), "before reload: {}", lines[1]);
    assert!(lines[2].contains(r#""reloaded":true"#), "reload reply: {}", lines[2]);
    assert!(lines[3].contains(r#""scores":[5]"#), "after reload: {}", lines[3]);
}

#[test]
fn errors_never_end_the_session() {
    // Eight distinct failure shapes, then a healthy request: the
    // session must answer all nine and end only at EOF.
    let deep = format!("{}{}", "[".repeat(100), "]".repeat(100));
    let bad: Vec<String> = vec![
        "garbage".to_string(),
        "[1,2,3]".to_string(),
        r#"{"op":"frobnicate"}"#.to_string(),
        r#"{"op":"score","kind":"S","records":[[1]]}"#.to_string(),
        r#"{"op":"load","model":"not a model"}"#.to_string(),
        r#"{"op":"load","kind":"Q","model":"spp-model v1 task=regression lambda=1 b=0\n"}"#
            .to_string(),
        r#"{"op":"score","kind":"I","records":"nope"}"#.to_string(),
        deep,
    ];
    let input = bad.join("\n") + "\n" + r#"{"op":"list"}"# + "\n";
    let lines = session(1, &input);
    assert_eq!(lines.len(), 9);
    for (i, l) in lines.iter().take(8).enumerate() {
        assert!(l.starts_with(r#"{"spp":1,"ok":false"#), "line {i} should be an error: {l}");
    }
    assert!(lines[8].starts_with(r#"{"spp":1,"ok":true"#), "survivor: {}", lines[8]);
}

#[test]
fn ids_echo_on_success_and_error() {
    let input = r#"{"op":"list","id":"alpha"}
{"op":"frobnicate","id":[1,{"k":2}]}
"#;
    let lines = session(1, input);
    assert!(lines[0].starts_with(r#"{"spp":1,"ok":true,"id":"alpha""#), "{}", lines[0]);
    assert!(lines[1].starts_with(r#"{"spp":1,"ok":false,"id":[1,{"k":2}]"#), "{}", lines[1]);
}

/// The full canned session CI pipes through `spp serve --stdio`,
/// replayed in-process: output must equal the checked-in golden
/// byte for byte, at one worker and at four.
#[test]
fn golden_smoke_session_replays_byte_identically() {
    let input = include_str!("data/serve_smoke.jsonl");
    let golden = include_str!("data/serve_smoke.golden.jsonl");
    for threads in [1usize, 4] {
        let lines = session(threads, input);
        let got = lines.join("\n") + "\n";
        assert_eq!(got, golden, "golden mismatch at threads={threads}");
    }
}

/// Scoring a mined model over the wire: compiled and naive matchers
/// must produce byte-identical score lines, and the whole session must
/// be byte-identical across thread counts.
#[test]
fn wire_scores_agree_between_matchers_and_thread_counts() {
    let info = registry::info("synth-seq").unwrap();
    let data = registry::lookup("synth-seq", 0.1).unwrap();
    let model = mined_model(&data, info.task, 2, 2);
    let text = model.serialize().unwrap();
    let Dataset::Sequences(s) = &data else { panic!("synth-seq is a sequence dataset") };
    let records: Vec<String> = s.db.seqs[..20.min(s.db.seqs.len())]
        .iter()
        .map(|seq| {
            let inner: Vec<String> = seq.iter().map(|x| x.to_string()).collect();
            format!("[{}]", inner.join(","))
        })
        .collect();
    let records = format!("[{}]", records.join(","));
    let load = format!(
        r#"{{"op":"load","model":"{}"}}"#,
        text.replace('\\', "\\\\").replace('\n', "\\n")
    );
    let score_compiled = format!(r#"{{"op":"score","kind":"S","records":{records}}}"#);
    let score_naive =
        format!(r#"{{"op":"score","kind":"S","records":{records},"matcher":"naive"}}"#);
    let input = format!("{load}\n{score_compiled}\n{score_naive}\n");
    let base = session(1, &input);
    assert!(base[0].contains(r#""ok":true"#), "load failed: {}", base[0]);
    // compiled vs naive: the emitted scores and preds (everything
    // after the "scores" key) must be byte-identical
    let scores_of = |l: &str| l.split(r#""scores":"#).nth(1).unwrap().to_string();
    assert_eq!(
        scores_of(&base[1]),
        scores_of(&base[2]),
        "compiled and naive disagree over the wire"
    );
    for threads in [2usize, 4] {
        assert_eq!(session(threads, &input), base, "session bytes differ at threads={threads}");
    }
}
