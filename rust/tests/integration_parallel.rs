//! The deterministic parallel engine's equivalence contract, end to
//! end: a path computed with `--threads 4` must be **bit-identical** to
//! `--threads 1` — same active sets (patterns and order), same weights
//! and intercepts to the bit, same certified gaps, same traversed-node
//! counts and reuse telemetry — on all three shipped substrates, in
//! both the forest-reuse and from-scratch screening configurations, and
//! with dynamic screening / certify toggled.  CI's `test-matrix` job
//! additionally runs the whole suite under `SPP_THREADS ∈ {1, 4}`, so
//! the auto default is exercised at both worker counts on every push.

use spp::data::sequence::{self, SeqSynthConfig};
use spp::data::synth_graphs::{self, GraphSynthConfig};
use spp::data::synth_itemsets::{self, ItemsetSynthConfig};
use spp::mining::PatternSubstrate;
use spp::path::cv::cross_validate;
use spp::path::{compute_path_spp, PathConfig, PathResult};
use spp::solver::Task;

fn cfg(n_lambdas: usize, maxpat: usize, reuse: bool) -> PathConfig {
    PathConfig {
        n_lambdas,
        lambda_min_ratio: 0.05,
        maxpat,
        reuse_forest: reuse,
        ..PathConfig::default()
    }
}

/// Bitwise path equality: everything except wall-clock seconds.
fn assert_bit_identical(seq: &PathResult, par: &PathResult) {
    assert_eq!(seq.lambda_max.to_bits(), par.lambda_max.to_bits());
    assert_eq!(seq.points.len(), par.points.len());
    for (a, b) in seq.points.iter().zip(&par.points) {
        assert_eq!(a.lambda.to_bits(), b.lambda.to_bits());
        assert_eq!(
            a.active.len(),
            b.active.len(),
            "active-set size mismatch at λ={}: {} vs {}",
            a.lambda,
            a.active.len(),
            b.active.len()
        );
        for ((pa, wa), (pb, wb)) in a.active.iter().zip(&b.active) {
            assert_eq!(pa, pb, "active pattern/order mismatch at λ={}", a.lambda);
            assert_eq!(
                wa.to_bits(),
                wb.to_bits(),
                "weight bits differ at λ={} on {}: {wa} vs {wb}",
                a.lambda,
                pa.display()
            );
        }
        assert_eq!(a.b.to_bits(), b.b.to_bits(), "intercept bits at λ={}", a.lambda);
        assert_eq!(a.gap.to_bits(), b.gap.to_bits(), "gap bits at λ={}", a.lambda);
        assert!(a.gap <= 2e-6, "uncertified λ={}", a.lambda);
        // identical tree work and identical engine decisions
        assert_eq!(a.stats, b.stats, "node counts at λ={}", a.lambda);
        assert_eq!(a.working_size, b.working_size, "|Â| at λ={}", a.lambda);
        assert_eq!(a.cd_epochs, b.cd_epochs, "solver epochs at λ={}", a.lambda);
        assert_eq!(a.reuse, b.reuse, "reuse telemetry at λ={}", a.lambda);
    }
}

/// `threads = 1` vs `threads = 4` on one substrate/config; returns the
/// parallel run for further inspection.
fn case<S: PatternSubstrate>(db: &S, y: &[f64], task: Task, base: &PathConfig) -> PathResult {
    let mut seq_cfg = *base;
    seq_cfg.threads = 1;
    let mut par_cfg = *base;
    par_cfg.threads = 4;
    let seq = compute_path_spp(db, y, task, &seq_cfg).unwrap();
    let par = compute_path_spp(db, y, task, &par_cfg).unwrap();
    assert_bit_identical(&seq, &par);
    // the sequential engine must report itself as such
    assert!(seq.points.iter().all(|p| p.threads.workers == 1));
    par
}

#[test]
fn itemsets_bit_identical_both_tasks_both_engines() {
    for (seed, classify) in [(71u64, false), (72, true)] {
        let d = synth_itemsets::generate(&ItemsetSynthConfig::tiny(seed, classify));
        let task = if classify {
            Task::Classification
        } else {
            Task::Regression
        };
        for reuse in [true, false] {
            let par = case(&d.db, &d.y, task, &cfg(10, 3, reuse));
            // the 4-worker run must actually have fanned out somewhere
            assert!(
                par.points.iter().any(|p| p.threads.workers > 1),
                "reuse={reuse}: no screening phase used more than one worker"
            );
        }
    }
}

#[test]
fn graphs_bit_identical_both_engines() {
    for (seed, classify) in [(73u64, false), (74, true)] {
        let d = synth_graphs::generate(&GraphSynthConfig::tiny(seed, classify));
        let task = if classify {
            Task::Classification
        } else {
            Task::Regression
        };
        for reuse in [true, false] {
            case(&d.db, &d.db.y, task, &cfg(10, 3, reuse));
        }
    }
}

#[test]
fn sequences_bit_identical_both_engines() {
    for (seed, classify) in [(75u64, false), (76, true)] {
        let d = sequence::generate(&SeqSynthConfig::tiny(seed, classify));
        let task = if classify {
            Task::Classification
        } else {
            Task::Regression
        };
        for reuse in [true, false] {
            case(&d.db, &d.y, task, &cfg(10, 3, reuse));
        }
    }
}

#[test]
fn dynamic_screen_and_certify_configurations_stay_identical() {
    let d = synth_itemsets::generate(&ItemsetSynthConfig::tiny(77, true));
    // dynamic screening off
    let mut c = cfg(10, 3, true);
    c.cd.dynamic_screen = false;
    case(&d.db, &d.y, Task::Classification, &c);
    // certify pass on, scratch engine
    let mut c = cfg(8, 3, false);
    c.certify = true;
    case(&d.db, &d.y, Task::Classification, &c);
    // certify + forest
    let mut c = cfg(8, 3, true);
    c.certify = true;
    case(&d.db, &d.y, Task::Classification, &c);
}

#[test]
fn worker_counts_beyond_the_task_count_change_nothing() {
    let d = sequence::generate(&SeqSynthConfig::tiny(78, false));
    let base = cfg(8, 2, false);
    let mut seq_cfg = base;
    seq_cfg.threads = 1;
    let seq = compute_path_spp(&d.db, &d.y, Task::Regression, &seq_cfg).unwrap();
    for threads in [2usize, 3, 16] {
        let mut c = base;
        c.threads = threads;
        let par = compute_path_spp(&d.db, &d.y, Task::Regression, &c).unwrap();
        assert_bit_identical(&seq, &par);
    }
}

#[test]
fn parallel_telemetry_reports_workers_and_tasks() {
    let d = synth_itemsets::generate(&ItemsetSynthConfig::tiny(79, false));
    let mut c = cfg(8, 3, false);
    c.threads = 4;
    let par = compute_path_spp(&d.db, &d.y, Task::Regression, &c).unwrap();
    // λ_max point is always sequential
    assert_eq!(par.points[0].threads.workers, 1);
    // scratch screening farms one task per root item
    let busy = par
        .points
        .iter()
        .skip(1)
        .filter(|p| p.threads.workers > 1)
        .collect::<Vec<_>>();
    assert!(!busy.is_empty(), "4-worker scratch path never fanned out");
    for p in &busy {
        assert!(p.threads.workers <= 4);
        assert!(p.threads.tasks >= p.threads.workers);
    }
}

#[test]
fn cross_validation_folds_are_bit_identical_across_worker_counts() {
    let d = synth_itemsets::generate(&ItemsetSynthConfig::tiny(80, false));
    let mut c1 = cfg(6, 2, true);
    c1.threads = 1;
    let mut c4 = c1;
    c4.threads = 4;
    let a = cross_validate(&d.db, &d.y, Task::Regression, &c1, 4, 7).unwrap();
    let b = cross_validate(&d.db, &d.y, Task::Regression, &c4, 4, 7).unwrap();
    assert_eq!(a.best, b.best);
    assert_eq!(a.points.len(), b.points.len());
    for (p, q) in a.points.iter().zip(&b.points) {
        assert_eq!(p.lambda_frac.to_bits(), q.lambda_frac.to_bits());
        assert_eq!(p.mean_loss.to_bits(), q.mean_loss.to_bits());
        assert_eq!(p.mean_active.to_bits(), q.mean_active.to_bits());
        assert_eq!(p.fold_losses.len(), q.fold_losses.len());
        for (x, y) in p.fold_losses.iter().zip(&q.fold_losses) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}
