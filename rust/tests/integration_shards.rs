//! Out-of-core sharded databases, end to end.
//!
//! Three properties pin the storage layer:
//!
//! 1. **Remap**: the global↔local record-id arithmetic survives the
//!    pathological shard sizes (1 record per shard, boundary ±1, a
//!    last shard holding a single record), `select` handles arbitrary
//!    permuted/duplicated index lists, and the materialized union is
//!    record-identical to the source database.
//! 2. **Differential path**: the full SPP path over a file-backed
//!    [`ShardedDb`] is **bit-identical** to the in-memory path — same
//!    λ grid, active sets, weight/intercept/gap bits, same |Â| and
//!    solver trajectory — on all three substrates, at 1 and 4 threads.
//! 3. **Spill ceiling**: a small `memory_budget` leaves every path
//!    point's post-enforcement resident-byte gauge at or under the
//!    budget, moves real traffic through the spill tier (evictions and
//!    reloads), and never changes a single output bit — for the SPP
//!    forest engine, the per-λ scratch engine and the boosting
//!    baseline alike.

use std::path::PathBuf;

use spp::data::registry::{self, Dataset, ShardedDataset};
use spp::data::synth_itemsets::{self, ItemsetSynthConfig};
use spp::data::Transactions;
use spp::mining::PatternSubstrate;
use spp::path::{compute_path_boosting, compute_path_spp, PathConfig, PathResult};
use spp::solver::Task;
use spp::storage::{read_index, write_sharded, ShardedDb};

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("spp-it-shards-{tag}-{}", std::process::id()))
}

fn cfg(n_lambdas: usize, maxpat: usize) -> PathConfig {
    PathConfig {
        n_lambdas,
        lambda_min_ratio: 0.05,
        maxpat,
        threads: 1,
        ..PathConfig::default()
    }
}

/// Bitwise equality of everything the solver produced (telemetry and
/// wall-clock excluded — where the traversal work happens is exactly
/// what the storage layer is allowed to move).
fn assert_paths_bitwise(a: &PathResult, b: &PathResult) {
    assert_eq!(a.lambda_max.to_bits(), b.lambda_max.to_bits());
    assert_eq!(a.points.len(), b.points.len());
    for (p, q) in a.points.iter().zip(&b.points) {
        assert_eq!(p.lambda.to_bits(), q.lambda.to_bits());
        assert_eq!(
            p.active.len(),
            q.active.len(),
            "active-set size mismatch at λ={}: {} vs {}",
            p.lambda,
            p.active.len(),
            q.active.len()
        );
        for ((pa, wa), (pb, wb)) in p.active.iter().zip(&q.active) {
            assert_eq!(pa, pb, "active pattern/order mismatch at λ={}", p.lambda);
            assert_eq!(
                wa.to_bits(),
                wb.to_bits(),
                "weight bits differ at λ={} on {}: {wa} vs {wb}",
                p.lambda,
                pa.display()
            );
        }
        assert_eq!(p.b.to_bits(), q.b.to_bits(), "intercept bits at λ={}", p.lambda);
        assert_eq!(p.gap.to_bits(), q.gap.to_bits(), "gap bits at λ={}", p.lambda);
        assert!(p.gap <= 2e-6, "uncertified λ={}", p.lambda);
        assert_eq!(p.working_size, q.working_size, "|Â| at λ={}", p.lambda);
        assert_eq!(p.cd_epochs, q.cd_epochs, "solver epochs at λ={}", p.lambda);
    }
}

#[test]
fn remap_survives_pathological_shard_sizes() {
    let d = synth_itemsets::generate(&ItemsetSynthConfig::tiny(301, false));
    let n = d.db.len();
    assert!(n >= 4, "tiny preset too tiny for boundary cases ({n})");
    let dir = tmp("remap");
    std::fs::create_dir_all(&dir).unwrap();
    // 1 record/shard; boundary ±1 around a mid split; a full-db shard;
    // oversized (single-shard); and a last shard holding ONE record
    let sizes = [1, 2, (n + 1) / 2, n - 1, n, n + 3];
    for (case, &size) in sizes.iter().enumerate() {
        let path = dir.join(format!("case{case}.spps"));
        let index = write_sharded(&d.db, &path, size).unwrap();
        let n_shards = (n + size - 1) / size;
        assert_eq!(index.n_shards(), n_shards, "size={size}");
        assert_eq!(index.n_records, n);
        assert_eq!(index.shard_size, size);
        // the footer read back from disk agrees with the writer's index
        let reread = read_index(&path).unwrap();
        assert_eq!(reread.n_records, index.n_records);
        assert_eq!(reread.shard_size, index.shard_size);
        assert_eq!(reread.n_shards(), index.n_shards());

        let db = ShardedDb::<Transactions>::open(&path).unwrap();
        assert_eq!(db.n_records(), n);
        assert_eq!(db.n_shards(), n_shards);
        // global↔local arithmetic, every record
        let mut total = 0usize;
        for s in 0..n_shards {
            let base = db.shard_base(s);
            let cnt = db.shard_records(s);
            assert!(cnt >= 1, "size={size}: empty shard {s}");
            assert_eq!(base, s * size);
            for local in 0..cnt {
                assert_eq!(db.locate(base + local), (s, local), "size={size}");
            }
            // per-shard rows are exactly the source's contiguous run
            let shard = db.shard(s).unwrap();
            assert_eq!(shard.items.len(), cnt);
            assert_eq!(&shard.items[..], &d.db.items[base..base + cnt], "size={size}");
            total += cnt;
        }
        assert_eq!(total, n, "size={size}: shard records don't cover the db");
        // last shard of `n - 1` holds exactly one record
        if size == n - 1 {
            assert_eq!(db.shard_records(n_shards - 1), 1);
        }
        // the union is record-identical to the source
        let union = db.materialize().unwrap();
        assert_eq!(union.n_items, d.db.n_items);
        assert_eq!(union.items, d.db.items);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn select_on_sharded_matches_in_memory_select() {
    let d = synth_itemsets::generate(&ItemsetSynthConfig::tiny(302, true));
    let n = d.db.len();
    let dir = tmp("select");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("db.spps");
    write_sharded(&d.db, &path, (n + 2) / 3).unwrap();
    let db = ShardedDb::<Transactions>::open(&path).unwrap();
    // permuted, duplicated, cross-shard index lists — including one
    // that revisits the same record with other shards in between
    let picks: [Vec<usize>; 4] = [
        (0..n).rev().collect(),
        vec![n - 1, 0, n / 2, 0, n - 1, n - 1],
        vec![1; 5],
        (0..n).step_by(2).chain(0..n).collect(),
    ];
    for idx in &picks {
        let got = db.select(idx);
        let want = d.db.select(idx);
        assert_eq!(got.n_records(), idx.len());
        assert_eq!(got.as_mem().unwrap().items, want.items, "{idx:?}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// In-memory vs file-backed sharded path on one registry preset, the
/// sharded run at 1 and at 4 threads — all bit-identical.
fn preset_case(name: &str, scale: f64, n_lambdas: usize) {
    let dir = tmp(&format!("path-{name}"));
    let info = registry::info(name).unwrap();
    let base = cfg(n_lambdas, 3);
    let mem = registry::lookup(name, scale).unwrap();
    let a = match &mem {
        Dataset::Itemsets(t) => compute_path_spp(&t.db, &t.y, info.task, &base),
        Dataset::Graphs(g) => compute_path_spp(g, &g.y, info.task, &base),
        Dataset::Sequences(s) => compute_path_spp(&s.db, &s.y, info.task, &base),
    }
    .unwrap();
    let sharded = registry::lookup_sharded(name, scale, 3, &dir).unwrap();
    for threads in [1usize, 4] {
        let mut c = base;
        c.threads = threads;
        let b = match &sharded {
            ShardedDataset::Itemsets { db, y } => compute_path_spp(db, y, info.task, &c),
            ShardedDataset::Graphs { db, y } => compute_path_spp(db, y, info.task, &c),
            ShardedDataset::Sequences { db, y } => compute_path_spp(db, y, info.task, &c),
        }
        .unwrap();
        assert_paths_bitwise(&a, &b);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sharded_path_bit_identical_itemsets() {
    preset_case("splice", 0.05, 6);
}

#[test]
fn sharded_path_bit_identical_graphs() {
    preset_case("cpdb", 0.1, 5);
}

#[test]
fn sharded_path_bit_identical_sequences() {
    preset_case("synth-seq", 0.1, 5);
}

const BUDGET: usize = 4096;

#[test]
fn spill_budget_is_bit_identical_with_bounded_residency() {
    let data = registry::lookup("splice", 0.1).unwrap();
    let Dataset::Itemsets(t) = &data else {
        unreachable!()
    };
    for reuse in [true, false] {
        let mut unlimited = cfg(8, 3);
        unlimited.reuse_forest = reuse;
        let mut budgeted = unlimited;
        budgeted.memory_budget = BUDGET;
        let a = compute_path_spp(&t.db, &t.y, Task::Classification, &unlimited).unwrap();
        let b = compute_path_spp(&t.db, &t.y, Task::Classification, &budgeted).unwrap();
        assert_paths_bitwise(&a, &b);
        // the unlimited run never touches the spill tier
        assert_eq!(a.total_spill_evictions(), 0);
        assert_eq!(a.total_spill_reloads(), 0);
        // the budgeted run moves real traffic through it...
        assert!(b.total_spill_evictions() > 0, "reuse={reuse}: budget never bit");
        if reuse {
            // ...and the forest engine restores residency every λ
            assert!(b.total_spill_reloads() > 0, "forest never reloaded");
        }
        // ...while the post-enforcement gauge stays at or under budget
        for p in &b.points {
            assert!(
                p.spill.resident_bytes <= BUDGET,
                "reuse={reuse}: resident {} > budget {BUDGET} at λ={}",
                p.spill.resident_bytes,
                p.lambda
            );
        }
        // so its peak gauge sits strictly under the unlimited run's
        assert!(b.max_resident_bytes() < a.max_resident_bytes(), "reuse={reuse}");
    }
}

#[test]
fn boosting_budget_is_bit_identical_and_enforced_at_lambda_boundaries() {
    let data = registry::lookup("splice", 0.08).unwrap();
    let Dataset::Itemsets(t) = &data else {
        unreachable!()
    };
    let unlimited = cfg(6, 3);
    let mut budgeted = unlimited;
    budgeted.memory_budget = BUDGET;
    let a = compute_path_boosting(&t.db, &t.y, Task::Classification, &unlimited).unwrap();
    let b = compute_path_boosting(&t.db, &t.y, Task::Classification, &budgeted).unwrap();
    assert_paths_bitwise(&a, &b);
    assert!(b.total_spill_evictions() > 0, "budget never bit");
    assert!(b.total_spill_reloads() > 0, "λ-boundary restore never ran");
    for p in &b.points {
        assert!(p.spill.resident_bytes <= BUDGET, "resident gauge over budget");
    }
}

#[test]
fn sharded_path_with_budget_composes() {
    // the tentpole end state: records on disk AND columns under a
    // budget, still bit-identical to the fully-resident run
    let dir = tmp("compose");
    let mem = registry::lookup("splice", 0.08).unwrap();
    let Dataset::Itemsets(t) = &mem else {
        unreachable!()
    };
    let a = compute_path_spp(&t.db, &t.y, Task::Classification, &cfg(6, 3)).unwrap();
    let sharded = registry::lookup_sharded("splice", 0.08, 4, &dir).unwrap();
    let ShardedDataset::Itemsets { db, y } = &sharded else {
        unreachable!()
    };
    let mut c = cfg(6, 3);
    c.memory_budget = BUDGET;
    let b = compute_path_spp(db, y, Task::Classification, &c).unwrap();
    assert_paths_bitwise(&a, &b);
    assert!(b.total_spill_evictions() > 0);
    let _ = std::fs::remove_dir_all(&dir);
}
